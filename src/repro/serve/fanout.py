"""Serve-plane multicast: request fan-out on the stacked group substrate
(DESIGN.md Sec. 6).

The paper's end-to-end payoff is the OMG-DDS built over Derecho inheriting
the batching and null-send optimizations; the analogue here is the serving
plane riding the multicast substrate.  :class:`ReplicatedEngine` runs G
replica :class:`~repro.serve.engine.ServeEngine`\\ s and publishes every
decode round's events — admitted requests and emitted tokens — as
messages on one DDS topic per replica, streamed through the SAME stacked
compiled program that runs benchmark scenarios
(:meth:`repro.core.dds.Domain.bind` ->
:class:`repro.core.group.GroupStream`): engine slots x replica subgroups,
one dispatch per engine round, one trace for the whole serve session.

The slot ring IS the SMC ring, explicitly:

* **senders = slots.**  Each topic's publishers are the replica's KV
  slots (one multicast sender rank per slot), so the admission order is
  the protocol's round-robin (``rr_prefix_masked``) total order.
* **stalled clients = null-send rounds.**  A slot whose client applies
  backpressure decodes a null step and publishes nothing; the null-send
  scheme covers its rank so every other slot's tokens keep delivering.
* **slot free = delivery watermark.**  A completed request's slot may
  admit new work only once the multicast watermark shows its last token
  message delivered at every subscriber — the SMC slot-reuse rule applied
  to KV-cache slots.

:meth:`ReplicatedEngine.run` returns the multicast
:class:`~repro.core.group.RunReport` merged with serving metrics
(``extras["serve"]``: tokens/s, decode steps, stall rounds) so one record
carries tokens/s alongside multicast duration/rdma_writes.
"""

from __future__ import annotations

import dataclasses
import time
from typing import (Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.core import dds
from repro.core import views as views_mod
from repro.core.group import RunReport
from repro.load.admission import ServeAdmission
from repro.serve.engine import Request, ServeEngine

# stall_fn(replica, engine_round) -> slots whose client is backpressured
StallFn = Callable[[int, int], Sequence[int]]

# arrive_fn(replica, engine_round) -> requests arriving open-loop that
# round (the workload plane's injection point — DESIGN.md Sec. 10)
ArriveFn = Callable[[int, int], Sequence[Request]]


def _as_waves(spec) -> List[List[int]]:
    """Normalize one ``fail_at`` value: a flat node sequence is a single
    suspicion batch; a sequence of sequences is a CASCADE — later waves
    land while the wedge for the first is in progress and fold into the
    same installed view (DESIGN.md Sec. 7).  Mixing the two shapes in
    one value is ambiguous and raises."""
    spec = list(spec)
    nested = [isinstance(w, (list, tuple, set, frozenset)) for w in spec]
    if all(nested) and spec:
        return [sorted(int(n) for n in w) for w in spec if w]
    if any(nested):
        raise ValueError(
            "fail_at value mixes node ids and waves: use either a flat "
            "sequence of nodes or a sequence of waves")
    return [sorted(int(n) for n in spec)] if spec else []


@dataclasses.dataclass
class _SlotHold:
    """A completed request whose slot awaits the delivery watermark."""

    target_apps: int                 # enqueued app messages at finish time
    last_idx: Optional[int] = None   # publish index of the last app msg
    finished_round: int = 0


class ReplicatedEngine:
    """G replica serve engines whose decode rounds ride one stacked
    multicast program.

    ``engines`` are the replicas (any mix of shapes; replica ``g``'s
    topic gets one sender rank per KV slot).  Each replica's topic is
    subscribed by ``subscribers_per_replica`` follower nodes (standbys /
    response loggers — the processes that must observe the replica's
    admission+token stream in total order).  ``stall_fn(g, round)`` names
    the slots of replica ``g`` whose client is backpressured that engine
    round; a precomputed boolean ``(rounds, G, slots)`` ndarray is also
    accepted — that form stays on the fused path (a callable falls back,
    see :mod:`repro.serve.fused`).  ``window`` is the per-slot SMC ring
    window: how many
    undelivered messages a slot may have in flight before the send
    predicate throttles it.
    """

    def __init__(self, engines: Sequence[ServeEngine], *,
                 subscribers_per_replica: int = 1, window: int = 8,
                 sample_size: int = 2048,
                 qos: dds.QoS = dds.QoS.ATOMIC_MULTICAST,
                 backend: str = "graph",
                 stall_fn: Optional[StallFn] = None):
        if not engines:
            raise ValueError("need at least one replica engine")
        self.engines = list(engines)
        self.backend = backend
        self.stall_fn = stall_fn
        self._slots = [eng.ecfg.max_batch for eng in self.engines]
        # Slot nodes are numbered BELOW the replica's subscriber nodes so
        # each topic's publishers are its first members in slot order —
        # sender rank s == slot s (the sweep's rank convention).
        node = 0
        self.domain = dds.Domain(n_nodes=0)
        self.topics: List[dds.Topic] = []
        self._slot_nodes: List[List[int]] = []   # replica -> slot -> node
        self._node_to_slot: Dict[int, Tuple[int, int]] = {}  # node -> (g, s)
        for g, b in enumerate(self._slots):
            slot_nodes = list(range(node, node + b))
            subs = list(range(node + b,
                              node + b + subscribers_per_replica))
            node += b + subscribers_per_replica
            self.domain.n_nodes = node
            self.topics.append(self.domain.create_topic(
                f"replica-{g}", publishers=slot_nodes, subscribers=subs,
                sample_size=sample_size, qos=qos, window=window))
            self._slot_nodes.append(slot_nodes)
            for s, n in enumerate(slot_nodes):
                self._node_to_slot[n] = (g, s)
        # per-run traces (tests read these)
        self.admit_rounds: Dict[int, int] = {}       # rid -> engine round
        self.admit_slots: Dict[int, Tuple[int, int]] = {}  # rid -> (g, s)
        self.finish_rounds: List[Tuple[int, int, int]] = []  # (g, s, rnd)
        self.free_rounds: List[Tuple[int, int, int]] = []    # (g, s, rnd)
        self.stall_rounds = 0
        # open-loop traces (the workload plane reads these)
        self.submit_rounds: Dict[int, int] = {}      # rid -> arrival rnd
        self.finish_round_by_rid: Dict[int, int] = {}
        self.shed_log: List[Tuple[int, int]] = []    # (rid, round shed)
        self.queue_depth_log: List[int] = []         # total queued / rnd
        self.backlog_log: List[int] = []             # stream backlog / rnd
        self._last_view = None
        self.last_report: Optional[RunReport] = None
        # mid-run view changes (fail_at): one entry per installed view —
        # (engine round, View, closing-epoch report, {topic: cut log})
        self.view_log: List[Tuple[int, "views_mod.View", RunReport,
                                  Dict[str, object]]] = []

    # -- bookkeeping ---------------------------------------------------------

    def _reset_run_state(self):
        g_n = len(self.engines)
        self._apps_enqueued = [np.zeros(b, np.int64) for b in self._slots]
        self._holds: List[Dict[int, _SlotHold]] = [{} for _ in
                                                   range(g_n)]
        self.admit_rounds = {}
        self.admit_slots = {}
        self.finish_rounds = []
        self.free_rounds = []
        self.stall_rounds = 0
        self.submit_rounds = {}
        self.finish_round_by_rid = {}
        self.shed_log = []
        self.queue_depth_log = []
        self.backlog_log = []
        self._last_view = None
        self.view_log = []
        self._failed: set = set()
        # slot-node failure state: dead engine slots per replica, and the
        # live slot <-> sender-rank maps of the CURRENT view (a cut that
        # removes a slot node compacts the surviving slots, in slot
        # order, onto sender ranks 0..k-1 — declaration order is
        # preserved by dds reconfigure, so rank order == slot order)
        self._dead_slots: List[set] = [set() for _ in range(g_n)]
        self._rank_slot: List[List[int]] = [list(range(b))
                                            for b in self._slots]
        self._slot_rank: List[Dict[int, int]] = [
            {s: s for s in range(b)} for b in self._slots]
        self.slot_failures: List[Dict[str, object]] = []
        self.cut_walls: List[float] = []   # per installed view (wall s)
        # failures drive a real membership service so cascading waves
        # fold into ONE installed view (views.py propose_and_install)
        self._ms = views_mod.MembershipService(range(self.domain.n_nodes))

    def _sync_holds(self, stream, view, round_no: int):
        """Pin each pending hold to its last app message's publish index
        (:meth:`GroupStream.app_publish_index` — None while that message
        is still window-throttled) and release holds the delivery
        watermark has passed."""
        for g in range(len(self.engines)):
            watermark = view.sender_delivered(g)
            for slot in list(self._holds[g]):
                hold = self._holds[g][slot]
                rank = self._slot_rank[g][slot]   # holds live on live slots
                if hold.last_idx is None:
                    hold.last_idx = stream.app_publish_index(
                        g, rank, hold.target_apps)
                if hold.last_idx is not None and \
                        watermark[rank] > hold.last_idx:
                    del self._holds[g][slot]
                    self.free_rounds.append((g, slot, round_no))

    def _fail_nodes(self, bound: dds.BoundDomain,
                    waves: Sequence[Sequence[int]], round_no: int,
                    admission: Optional[ServeAdmission]
                    ) -> dds.BoundDomain:
        """Install ONE new view without the given nodes — subscribers
        and/or slot (publisher) nodes, possibly in cascading suspicion
        waves — and carry the serve state across the cut.

        **Cascade folding.**  ``waves[0]`` is the suspicion batch that
        triggers the wedge; each later wave lands *while the wedge is in
        progress* and folds into the same pending cut via
        :meth:`views.MembershipService.propose_and_install`'s
        ``during_wedge`` hook — exactly one view installs for the whole
        cascade, its trim computed over the final survivors (DESIGN.md
        Sec. 7).

        **Surviving slots.**  The cut restarts per-sender publish
        numbering, so a hold's ``target_apps`` — the k-th app publish
        its release waits on — is rebased by the apps that went STABLE
        at the cut (``EpochCarry.stable_apps``): if its last message was
        already delivered everywhere the hold frees right here;
        otherwise the remainder rides the resend backlog and the hold
        re-pins from the new epoch's traces (``last_idx`` reset).  The
        engine-side enqueued counters rebase identically, keeping them
        equal to the new stream's epoch-local enqueued counts.

        **Dead slots.**  A failed slot node's messages up to the ragged
        trim were delivered at every survivor (read off the closing
        report's ``stable_apps_by_old_rank`` — the carry drops dead
        senders); its unstable tail is delivered nowhere and dies with
        it.  The slot's hold (if its request had finished) is dropped —
        there is no slot left to free.  An in-flight decode is VOIDED
        (:meth:`ServeEngine.evict`): the request re-enters the head of
        the replica's admission queue to restart from its prompt on a
        surviving slot, or is shed if the queue is at ``queue_cap``
        (DESIGN.md Sec. 9 records this re-admission policy).  Surviving
        slots compact, in slot order, onto the new view's sender ranks.
        Raises if a replica would lose its last live slot — the engine
        would have no publisher lane left (a full-replica failure is a
        domain teardown, not a view change).

        Every event lands in :attr:`slot_failures`; each installed view
        is appended to :attr:`view_log` and its wall clock to
        :attr:`cut_walls`."""
        t0 = time.perf_counter()
        waves = [sorted(set(w)) for w in waves if w]
        failing = set().union(*[set(w) for w in waves])
        dead_by_g: Dict[int, set] = {}
        for n in failing:
            if n in self._node_to_slot:
                g, s = self._node_to_slot[n]
                dead_by_g.setdefault(g, set()).add(s)
        for g, dead in dead_by_g.items():
            if len(self._dead_slots[g] | dead) >= self._slots[g]:
                raise ValueError(
                    f"fail_at round {round_no} would kill every slot "
                    f"node of replica {g}: the engine would have no "
                    "publisher lane left — a full-replica failure is a "
                    "domain teardown, not a view change")
        ms = self._ms
        reporter = next((m for m in ms.view.members if m not in failing),
                        ms.view.members[0])
        for n in waves[0]:
            ms.suspect(reporter, n)

        def _during_wedge(svc, attempt):
            nxt = attempt + 1
            if nxt < len(waves):
                for n in waves[nxt]:
                    svc.suspect(reporter, n)

        old_rank_slot = [list(r) for r in self._rank_slot]
        view = ms.propose_and_install(
            {}, during_wedge=_during_wedge if len(waves) > 1 else None)
        new_bound, old_report, old_logs = bound.reconfigure(view)
        carry = new_bound.stream.carry
        stable_old = \
            old_report.extras["view_change"]["stable_apps_by_old_rank"]
        self._failed |= failing
        for g, eng in enumerate(self.engines):
            # dead slots first: account their stable prefix, void the
            # in-flight decode, drop their hold
            for slot in sorted(dead_by_g.get(g, ())):
                old_rank = old_rank_slot[g].index(slot)
                stable_cnt = int(stable_old[g][old_rank])
                rec = {"round": round_no, "replica": g, "slot": slot,
                       "node": self._slot_nodes[g][slot],
                       "stable_apps": stable_cnt,
                       "lost_apps":
                           int(self._apps_enqueued[g][slot]) - stable_cnt,
                       "voided_rid": None, "requeued": False,
                       "hold_dropped": slot in self._holds[g]}
                self._holds[g].pop(slot, None)
                req = eng.evict(slot)
                if req is not None:
                    rec["voided_rid"] = req.rid
                    if (admission is not None
                            and admission.queue_cap is not None
                            and len(eng.queue) >= admission.queue_cap):
                        self.shed_log.append((req.rid, round_no))
                    else:
                        eng.queue.appendleft(req)  # oldest work first
                        rec["requeued"] = True
                self._apps_enqueued[g][slot] = 0
                self._dead_slots[g].add(slot)
                self.slot_failures.append(rec)
            self._rank_slot[g] = [s for s in range(self._slots[g])
                                  if s not in self._dead_slots[g]]
            self._slot_rank[g] = {s: r for r, s in
                                  enumerate(self._rank_slot[g])}
            # surviving slots: rebase by what went stable at the cut
            stable = carry.stable_apps[g]
            for new_rank, slot in enumerate(self._rank_slot[g]):
                d = int(stable[new_rank])
                self._apps_enqueued[g][slot] -= d
                hold = self._holds[g].get(slot)
                if hold is not None:
                    hold.target_apps -= d
                    hold.last_idx = None        # old-epoch index is void
                    if hold.target_apps <= 0:   # stable at the cut: free
                        del self._holds[g][slot]
                        self.free_rounds.append((g, slot, round_no))
        self.view_log.append((round_no, view, old_report, old_logs))
        self.cut_walls.append(time.perf_counter() - t0)
        self._last_view = None       # old-epoch watermarks are void
        return new_bound

    # -- the fused serve+multicast loop --------------------------------------

    def submit(self, replica: int, req) -> None:
        self.engines[replica].submit(req)

    def run(self, *, max_rounds: int = 10_000,
            settle_max: Optional[int] = None,
            fail_at: Optional[Mapping[int, Sequence[int]]] = None,
            arrive_fn: Optional[ArriveFn] = None,
            arrive_schedule: Optional[Sequence[Sequence[
                Sequence[Request]]]] = None,
            arrive_rounds: int = 0,
            admission: Optional[ServeAdmission] = None,
            fused: bool = False
            ) -> RunReport:
        """Drive every replica to drain, one multicast round per engine
        round, then settle the multicast and return the merged report.

        ``fused=True`` executes the whole run as one compiled device
        program PER MEMBERSHIP EPOCH — decode, multicast sweep,
        watermark-gated slot reuse, open-loop arrivals, admission
        shed/stalls, stall schedules and the settle drain all inside a
        ``lax.while_loop`` (:mod:`repro.serve.fused`), with zero host
        round-trips between cuts
        (``extras["serve"]["host_hops"] == 0``).  ``fail_at`` wedges
        the fused loop at the failure round, performs the SAME host-side
        cut as this loop (:meth:`_fail_nodes`), and re-enters a fused
        program for the next epoch with the carry resend as its initial
        backlog — one cut = two device programs.  Precomputed dynamics
        stay fused: ``arrive_schedule`` (per-round request matrices),
        boolean ``(rounds, G, slots)`` ``stall_fn`` arrays, and
        :class:`~repro.load.admission.ServeAdmission` policies all
        lower to carry arithmetic.  Only what genuinely needs Python
        mid-round falls back to this per-round loop EXPLICITLY —
        arbitrary ``arrive_fn``/``stall_fn`` callables, ``settle_max``,
        heterogeneous replicas, and cuts that leave replicas with
        unequal slot/subscriber counts:
        ``extras["serve"]["fused"]`` is False and
        ``extras["serve"]["fused_fallback"]`` names the reason.

        Every engine round is ONE stacked-program dispatch across all G
        replica topics (the program is traced once per scenario shape —
        a whole run appends a single ``TRACE_EVENTS`` entry).  Admission
        into a freed slot is gated on the delivery watermark; requests
        queue behind held slots rather than overwrite undelivered ring
        state.

        Open-loop driving (DESIGN.md Sec. 10): ``arrive_fn(g, round)``
        injects that round's arriving requests into replica ``g``'s
        queue for the first ``arrive_rounds`` rounds — the loop keeps
        stepping through momentary drains while arrivals are still due,
        so traffic does not politely wait for the engines.  ``admission``
        (a :class:`repro.load.admission.ServeAdmission`) bounds the
        response to overload: queue tails beyond ``queue_cap`` are SHED
        (recorded in :attr:`shed_log` with their round), and a slot
        whose multicast lane has more than ``stall_backlog`` messages in
        flight (published-but-undelivered + window-throttled backlog,
        read off the previous round's watermarks) decodes a null round —
        the watermark-aware stall that expresses backpressure through
        the slot's SMC window.  Arrival, shed, and finish rounds land in
        :attr:`submit_rounds` / :attr:`shed_log` /
        :attr:`finish_round_by_rid`; per-round totals in
        :attr:`queue_depth_log` / :attr:`backlog_log`.

        ``fail_at`` maps an engine round to node ids that fail after
        that round's multicast dispatch — SUBSCRIBER nodes and/or SLOT
        (publisher) nodes, in any mix: the serve plane survives the
        mid-stream view change through the virtual-synchrony cut
        (DESIGN.md Sec. 7).  In-flight admissions/tokens are delivered
        everywhere at the ragged trim or resent in the new view's
        stream; every pending slot hold is RE-PINNED against the new
        epoch's watermarks; a dead slot node's unstable tail dies with
        it, its in-flight decode is voided and the request re-admitted
        or shed (see :meth:`_fail_nodes`; policy in DESIGN.md Sec. 9).
        A value may also be a sequence of node sequences — *cascading
        suspicion waves* that land while the wedge is in progress and
        fold into ONE installed view.  Each installed view is recorded
        in :attr:`view_log` with the closing epoch's report and
        cut-clipped per-topic logs; slot-kill events in
        :attr:`slot_failures`.  Scheduled rounds the run never reaches
        (the engines drained first — e.g. an earlier cut re-admitted
        work sooner) are NOT an error: they surface in
        ``extras["serve"]["fail_at_unreached"]``."""
        if arrive_schedule is not None and arrive_fn is not None:
            raise ValueError(
                "arrive_schedule and arrive_fn are mutually exclusive: "
                "a schedule IS the precomputed form of the callback")
        if arrive_schedule is not None and arrive_rounds <= 0:
            arrive_rounds = len(arrive_schedule)
        fail_at = {int(r): _as_waves(spec)
                   for r, spec in (fail_at or {}).items()}
        fail_at = {r: w for r, w in fail_at.items() if w}
        fused_fallback: Optional[str] = None
        if fused:
            from repro.serve import fused as fused_mod
            fused_fallback = fused_mod.fused_fallback_reason(
                self, fail_at=fail_at, arrive_fn=arrive_fn,
                arrive_schedule=arrive_schedule, admission=admission,
                settle_max=settle_max)
            if fused_fallback is None:
                try:
                    report = fused_mod.run_fused(
                        self, max_rounds=max_rounds, fail_at=fail_at,
                        arrive_schedule=arrive_schedule,
                        arrive_rounds=arrive_rounds,
                        admission=admission)
                except fused_mod.FusedUnsupported as e:
                    report, fused_fallback = None, str(e)
                if report is not None:
                    return report
                fused_fallback = fused_fallback or (
                    "run overflowed the fused round budget")
        # unfused path: a precomputed schedule / stall mask is just the
        # tabulated form of the callback — synthesize the callables so
        # both paths consume the identical workload description
        if arrive_schedule is not None:
            sched = [list(row) for row in arrive_schedule]
            arrive_fn = (lambda g, rnd:
                         sched[rnd][g] if rnd < len(sched) else ())
        stall_fn = self.stall_fn
        if isinstance(stall_fn, np.ndarray):
            stall_arr = stall_fn.astype(bool)
            stall_fn = (lambda g, rnd:
                        np.nonzero(stall_arr[rnd, g])[0]
                        if rnd < stall_arr.shape[0] else ())
        self._reset_run_state()
        bound = self.domain.bind(backend=self.backend)
        wall0 = time.perf_counter()
        # serve metrics are per-RUN deltas: engines accumulate completed
        # requests across runs (reset() clears them), and a second run
        # must not re-count — or re-rate — the first run's tokens
        tok0 = sum(len(r.tokens_out) for eng in self.engines
                   for r in eng.completed)
        req0 = sum(len(eng.completed) for eng in self.engines)
        steps0 = sum(eng.decode_steps for eng in self.engines)
        syncs0 = sum(eng.host_syncs for eng in self.engines)
        round_no = 0
        while (round_no < max_rounds
               and (round_no < arrive_rounds
                    or not all(eng.drained() for eng in self.engines))):
            if arrive_fn is not None and round_no < arrive_rounds:
                for g in range(len(self.engines)):
                    for req in arrive_fn(g, round_no) or ():
                        self.submit(g, req)
                        self.submit_rounds[req.rid] = round_no
            if admission is not None and admission.queue_cap is not None:
                for eng in self.engines:
                    while len(eng.queue) > admission.queue_cap:
                        dropped = eng.queue.pop()   # shed the tail
                        self.shed_log.append((dropped.rid, round_no))
            self.queue_depth_log.append(
                sum(len(eng.queue) for eng in self.engines))
            counts_by_topic = {}
            for g, eng in enumerate(self.engines):
                stalled = set(int(s) for s in stall_fn(g, round_no)) \
                    if stall_fn is not None else set()
                if (admission is not None
                        and admission.stall_backlog is not None
                        and self._last_view is not None):
                    v, k = self._last_view, len(self._rank_slot[g])
                    inflight = (v.published[g, :k]
                                - v.sender_delivered(g)[:k]
                                + v.backlog[g, :k])
                    stalled |= {self._rank_slot[g][int(r)] for r in
                                np.nonzero(inflight
                                           > admission.stall_backlog)[0]}
                held = self._holds[g]
                dead = self._dead_slots[g]
                mask = [s not in held and s not in dead
                        for s in range(self._slots[g])]
                info = eng.step(stalled=tuple(sorted(stalled)),
                                admit_mask=mask)
                self.stall_rounds += len(info.stalled)
                # counts are indexed by the CURRENT view's sender ranks
                # (surviving slots compacted in slot order)
                c = np.zeros(len(self._rank_slot[g]), np.int64)
                rank = self._slot_rank[g]
                for slot, rid in zip(info.admitted, info.admitted_rids):
                    c[rank[slot]] += 1         # the admitted-request batch
                    self.admit_rounds[rid] = round_no
                    self.admit_slots[rid] = (g, slot)
                for slot in info.emitted:
                    c[rank[slot]] += 1         # the emitted token
                    self._apps_enqueued[g][slot] += 1
                for slot in info.admitted:
                    self._apps_enqueued[g][slot] += 1
                for slot in info.finished:
                    self._holds[g][slot] = _SlotHold(
                        target_apps=int(self._apps_enqueued[g][slot]),
                        finished_round=round_no)
                    self.finish_rounds.append((g, slot, round_no))
                for rid in info.finished_rids:
                    self.finish_round_by_rid[rid] = round_no
                counts_by_topic[self.topics[g].name] = c
            view = bound.push_round(counts_by_topic)
            self._last_view = view
            self.backlog_log.append(int(sum(
                int(view.backlog[g, :len(self._rank_slot[g])].sum())
                for g in range(len(self.engines)))))
            self._sync_holds(bound.stream, view, round_no)
            if round_no in fail_at:
                bound = self._fail_nodes(bound, fail_at[round_no],
                                         round_no, admission)
            round_no += 1
        # A scheduled failure the run never reached became moot (an
        # earlier cut / drain landed first): surface it rather than
        # raise — the chaos harness samples schedules without knowing
        # drain times in advance (satellite of DESIGN.md Sec. 7).
        unreached = sorted(r for r in fail_at if r >= round_no)
        report, logs = bound.finish(settle_max=settle_max)
        # release holds the settle rounds delivered — including holds
        # whose last app message was still window-throttled when the
        # engines drained (unpinned): by quiescence it has published
        self._sync_holds(bound.stream, bound.stream.view(), round_no)
        wall = time.perf_counter() - wall0
        tokens = sum(len(r.tokens_out) for eng in self.engines
                     for r in eng.completed) - tok0
        report.extras["delivery_logs"] = logs
        report.extras["serve"] = {
            "replicas": len(self.engines),
            "engine_rounds": round_no,
            # False = max_rounds exhausted with work still queued/in
            # flight; the report then covers only what was served
            "drained": all(eng.drained() for eng in self.engines),
            "decode_steps": sum(e.decode_steps
                                for e in self.engines) - steps0,
            "requests": sum(len(e.completed)
                            for e in self.engines) - req0,
            "tokens": tokens,
            "tokens_per_s": tokens / wall if wall > 0 else 0.0,
            "stall_rounds": self.stall_rounds,
            "held_slots": sum(len(h) for h in self._holds),
            "view_changes": len(self.view_log),
            "slot_failures": len(self.slot_failures),
            "voided_requests": sum(1 for r in self.slot_failures
                                   if r["voided_rid"] is not None),
            "requeued_requests": sum(1 for r in self.slot_failures
                                     if r["requeued"]),
            "slot_failure_log": list(self.slot_failures),
            "fail_at_unreached": unreached,
            "shed_requests": len(self.shed_log),
            "max_queue_depth": max(self.queue_depth_log, default=0),
            "max_backlog": max(self.backlog_log, default=0),
            "wall_s": wall,
            "fused": False,
            # device->host syncs taken INSIDE the round loop: one logits
            # readback per engine decode + one watermark view per
            # multicast round — the per-round hop count the fused path
            # drives to zero
            "host_hops": (sum(eng.host_syncs for eng in self.engines)
                          - syncs0) + round_no,
        }
        if fused_fallback is not None:
            report.extras["serve"]["fused_fallback"] = fused_fallback
        self.last_report = report
        return report

    # -- results -------------------------------------------------------------

    def completed(self) -> Dict[int, List[List[int]]]:
        """Per replica: token streams of completed requests in rid order
        (accumulated since the last :meth:`reset`, like the engines'
        own ``completed`` lists — report metrics are per-run deltas)."""
        return {g: [r.tokens_out for r in
                    sorted(eng.completed, key=lambda r: r.rid)]
                for g, eng in enumerate(self.engines)}

    def reset(self) -> None:
        """Reset every replica engine (keeps params + compiled decode)."""
        for eng in self.engines:
            eng.reset()
