"""repro.api — the stable public surface of the repro.

One import gives the Derecho-style session API::

    from repro import api

    cfg = api.single_group(16, n_messages=1000)
    g = api.Group(cfg)
    g.subgroup(0).on_delivery(lambda member, msg: ...)
    report = g.run(backend="des")        # or "graph" / "pallas"

    # batched multi-scenario execution: a whole parameter grid as ONE
    # compiled program (graph/pallas) — see README "Performance"
    reports = g.run_batch(backend="graph", windows=[5, 20, 100, 500])

    # streaming execution: per-round message counts in, one stacked
    # program per round (the serve plane's entry point — DESIGN.md Sec. 6)
    stream = g.stream(backend="graph")
    stream.step(ready)                   # (G, S_max) counts this round
    report, logs = stream.finish()

Everything here is a re-export; the implementations live in
:mod:`repro.core.group` (the façade + backends + the compile-once scan
program cache), :mod:`repro.core.simulator` (flags/specs + the DES),
:mod:`repro.core.dds` (pub/sub) and :mod:`repro.core.views`
(virtual-synchrony membership).
"""

from repro.core.costmodel import HOST_X86, RDMA_CX6, TPU_ICI
from repro.core.dds import (BoundDomain, Domain, QoS, Topic,
                            many_topic_domain, single_topic_domain)
from repro.core.group import (BACKENDS, TRACE_MAXLEN, Delivery, DeliveryLog,
                              DESBackend, DESLoopBackend, EpochCarry,
                              GraphBackend, Group,
                              GroupConfig, GroupStream, PallasBackend,
                              ProtocolBackend, RunReport, SenderPattern,
                              SpindleFlags, StreamView, SubgroupHandle,
                              SubgroupSpec, get_backend, register_backend,
                              single_group, trace_reset, trace_snapshot)
from repro.core.views import MembershipService, View

# The serve-plane fan-out (repro.serve.fanout.ReplicatedEngine) is NOT
# re-exported here: it pulls in the model zoo, and repro.api stays a
# protocol-plane import.  ``from repro.serve.fanout import
# ReplicatedEngine`` is the serving entry point (DESIGN.md Sec. 6).
# The workload plane (repro.load) is protocol-plane and imported as
# ``from repro.load import ...`` (DESIGN.md Sec. 10).

__all__ = [
    "BACKENDS", "BoundDomain", "DESBackend", "DESLoopBackend", "Delivery",
    "DeliveryLog",
    "Domain", "EpochCarry", "GraphBackend", "Group", "GroupConfig",
    "GroupStream",
    "HOST_X86", "MembershipService", "PallasBackend", "ProtocolBackend",
    "QoS", "RDMA_CX6", "RunReport", "SenderPattern", "SpindleFlags",
    "StreamView", "SubgroupHandle", "SubgroupSpec", "TPU_ICI", "Topic",
    "TRACE_MAXLEN", "View", "get_backend", "many_topic_domain",
    "register_backend", "single_group", "single_topic_domain",
    "trace_reset", "trace_snapshot",
]
