"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Full configs are for the production mesh (see dryrun.py); on this CPU
container use ``--reduced`` to train the same family at smoke scale.
"""

from __future__ import annotations

import argparse

from repro.models import registry
from repro.models.runtime import Runtime
from repro.train.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (CPU-sized) config")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = registry.get(args.arch)
    cfg = arch.cfg.reduced() if args.reduced else arch.cfg
    tcfg = TrainConfig(steps=args.steps, seq_len=args.seq_len,
                       global_batch=args.global_batch,
                       checkpoint_dir=args.checkpoint_dir,
                       checkpoint_every=args.checkpoint_every,
                       log_every=args.log_every, seed=args.seed)
    trainer = Trainer(args.arch, cfg, tcfg, Runtime())
    trainer.run()


if __name__ == "__main__":
    main()
