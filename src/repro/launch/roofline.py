"""Roofline report: aggregate the dry-run cell JSONs into the Sec-Roofline
table (per arch x shape: three terms, dominant bottleneck, MODEL_FLOPS /
HLO_FLOPs ratio, and a one-line "what would move the dominant term").

Usage:
  PYTHONPATH=src python -m repro.launch.roofline [--dir results/dryrun]
      [--mesh pod16x16] [--format md|csv]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List, Optional


def load_cells(root: Path, mesh: str, variant: str = "") -> List[dict]:
    d = root / mesh / variant if variant else root / mesh
    cells = []
    for p in sorted(d.glob("*.json")):
        cells.append(json.loads(p.read_text()))
    return cells


_ADVICE = {
    "compute": ("compute-bound: raise per-chip utilization — bigger MXU "
                "tiles (128-aligned dims), fewer remat recomputes, or "
                "shrink the mesh for this model size"),
    "memory": ("memory-bound: cut HBM round-trips — chunked/flash "
               "attention instead of materialized scores, fuse norms into "
               "neighbors, lighter remat policy"),
    "collective": ("collective-bound: fewer/larger transfers — fuse "
                   "gradient buckets, swap all-reduce for reduce-scatter "
                   "via FSDP-friendly rules, overlap with compute"),
}


def advice(cell: dict) -> str:
    r = cell.get("roofline", {})
    dom = r.get("dominant", "")
    extra = ""
    frac = r.get("useful_flop_frac")
    if frac is not None and frac < 0.5 and dom == "compute":
        extra = " (useful-FLOP fraction <50%: remat/redundant compute)"
    return _ADVICE.get(dom, "") + extra


def row(cell: dict) -> Dict[str, str]:
    if cell.get("status") == "skipped":
        return {
            "arch": cell["arch"], "shape": cell["shape"],
            "status": "skipped", "compute_s": "", "memory_s": "",
            "collective_s": "", "dominant": "",
            "useful_flop_frac": "", "mfu_bound": "",
            "note": cell.get("reason", "")[:60],
        }
    if cell.get("status") != "ok":
        return {
            "arch": cell["arch"], "shape": cell["shape"],
            "status": "ERROR", "compute_s": "", "memory_s": "",
            "collective_s": "", "dominant": "",
            "useful_flop_frac": "", "mfu_bound": "",
            "note": cell.get("error", "")[:60],
        }
    r = cell["roofline"]
    return {
        "arch": cell["arch"], "shape": cell["shape"], "status": "ok",
        "compute_s": f"{r['compute_s']:.3f}",
        "memory_s": f"{r['memory_s']:.3f}",
        "collective_s": f"{r['collective_s']:.3f}",
        "dominant": r["dominant"],
        "useful_flop_frac": (f"{r['useful_flop_frac']:.2f}"
                             if r.get("useful_flop_frac") else ""),
        "mfu_bound": (f"{r['mfu_bound']*100:.2f}%"
                      if r.get("mfu_bound") else ""),
        "note": advice(cell)[:60],
    }


def render_md(cells: List[dict]) -> str:
    cols = ["arch", "shape", "status", "compute_s", "memory_s",
            "collective_s", "dominant", "useful_flop_frac", "mfu_bound"]
    lines = ["| " + " | ".join(cols) + " |",
             "|" + "|".join(["---"] * len(cols)) + "|"]
    for c in cells:
        r = row(c)
        lines.append("| " + " | ".join(r[k] for k in cols) + " |")
    return "\n".join(lines)


def render_csv(cells: List[dict]) -> str:
    cols = ["arch", "shape", "status", "compute_s", "memory_s",
            "collective_s", "dominant", "useful_flop_frac", "mfu_bound",
            "note"]
    lines = [",".join(cols)]
    for c in cells:
        r = row(c)
        lines.append(",".join(str(r[k]).replace(",", ";") for k in cols))
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--variant", default="")
    ap.add_argument("--format", default="md", choices=["md", "csv"])
    args = ap.parse_args()
    cells = load_cells(Path(args.dir), args.mesh, args.variant)
    if args.format == "md":
        print(render_md(cells))
    else:
        print(render_csv(cells))


if __name__ == "__main__":
    main()
