"""Trip-count-aware HLO analysis for the dry-run roofline.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, but every
model here scans its layers — an 80-layer scan would be undercounted 80x.
This module parses ``compiled.as_text()`` into its computation graph,
reads scan trip counts from ``backend_config.known_trip_count`` (falling
back to loop-condition constants), and accumulates:

  * dot FLOPs            (2 * |result| * |contracted dims|, trip-aware)
  * HBM traffic estimate (operand+result bytes of non-trivial instructions)
  * collective breakdown (count / operand bytes / ring-model wire bytes per
    op type, with replica-group sizes parsed per instruction)

The collective wire-bytes model (per participating device):
  all-reduce       2 (g-1)/g * B     (ring reduce-scatter + all-gather)
  all-gather       (g-1) * B         (B = per-device shard posted)
  reduce-scatter   (g-1)/g * B       (B = full per-device operand)
  all-to-all       (g-1)/g * B
  collective-permute   B
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OP_RE = re.compile(r"([a-z][\w\-]*)\(")
_REF_RE = re.compile(r"%([\w.\-]+)")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "iota", "partition-id", "replica-id"}


def _dims_of(dims: str) -> List[int]:
    return [int(d) for d in dims.split(",") if d]


def _bytes_of(dtype: str, dims: str) -> int:
    n = 1
    for d in _dims_of(dims):
        n *= d
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStat:
    count: int = 0
    operand_bytes: float = 0.0
    wire_bytes: float = 0.0


@dataclasses.dataclass
class _Instr:
    name: str
    op: str
    result_shapes: List[Tuple[str, str]]
    operand_refs: List[str]
    line: str

    def result_bytes(self) -> int:
        return sum(_bytes_of(d, s) for d, s in self.result_shapes)


@dataclasses.dataclass
class Metrics:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: Dict[str, CollectiveStat] = dataclasses.field(
        default_factory=dict)

    def add(self, other: "Metrics", mult: float = 1.0):
        self.dot_flops += other.dot_flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k, v in other.collectives.items():
            s = self.collectives.setdefault(k, CollectiveStat())
            s.count += int(v.count * mult)
            s.operand_bytes += v.operand_bytes * mult
            s.wire_bytes += v.wire_bytes * mult

    @property
    def collective_wire_bytes(self) -> float:
        return sum(s.wire_bytes for s in self.collectives.values())

    @property
    def collective_operand_bytes(self) -> float:
        return sum(s.operand_bytes for s in self.collectives.values())

    @property
    def collective_count(self) -> int:
        return sum(s.count for s in self.collectives.values())

    def to_dict(self) -> dict:
        return {
            "dot_flops": self.dot_flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_wire_bytes": self.collective_wire_bytes,
            "collective_operand_bytes": self.collective_operand_bytes,
            "collective_count": self.collective_count,
            "collectives": {
                k: dataclasses.asdict(v)
                for k, v in sorted(self.collectives.items())},
        }


def _parse(text: str):
    """-> (computations: name -> [instr], shapes: name -> (dtype, dims))."""
    comps: Dict[str, List[_Instr]] = {}
    shapes: Dict[str, Tuple[str, str]] = {}
    cur: Optional[str] = None
    for raw in text.splitlines():
        line = raw.strip()
        if raw and not raw.startswith(" ") and ("->" in raw):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", raw)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if line == "}":
            cur = None
            continue
        if cur is None or "=" not in line:
            continue
        lhs, rhs = line.split("=", 1)
        name = lhs.strip().lstrip("%").strip()
        mop = _OP_RE.search(rhs)
        if not mop:
            continue
        op = mop.group(1)
        # result type(s): between '=' and the opcode occurrence
        res_section = rhs[: mop.start()]
        res_shapes = _SHAPE_RE.findall(res_section)
        # operands: inside the eventual parens, up to attribute section
        paren = rhs[mop.end():]
        depth, end = 1, len(paren)
        for i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        arg_str = paren[:end]
        refs = _REF_RE.findall(arg_str)
        instr = _Instr(name=name, op=op, result_shapes=res_shapes,
                       operand_refs=refs, line=line)
        comps[cur].append(instr)
        if res_shapes:
            if len(res_shapes) == 1:
                shapes[name] = res_shapes[0]
            else:
                shapes[name] = ("tuple:" + ";".join(
                    f"{d}[{s}]" for d, s in res_shapes), "")
    return comps, shapes


def _shape_bytes_of_ref(shapes, ref: str) -> int:
    got = shapes.get(ref)
    if not got:
        return 0
    d, s = got
    if d.startswith("tuple:"):
        total = 0
        for part in _SHAPE_RE.findall(d):
            total += _bytes_of(*part)
        return total
    return _bytes_of(d, s)


def _group_size(line: str, total_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9, ]*)\}", line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(len(ids), 1)
    return total_devices


def _trip_count(instr: _Instr, comps, shapes) -> float:
    m = re.search(r'known_trip_count[^0-9]*"?n"?[^0-9]*(\d+)', instr.line)
    if m:
        return float(m.group(1))
    mc = re.search(r"condition=%?([\w.\-]+)", instr.line)
    if mc and mc.group(1) in comps:
        best = 1
        for sub in comps[mc.group(1)]:
            for mm in re.finditer(r"constant\((\d+)\)", sub.line):
                best = max(best, int(mm.group(1)))
        return float(best)
    return 1.0


def analyze_text(text: str, total_devices: int = 1) -> Metrics:
    comps, shapes = _parse(text)
    memo: Dict[str, Metrics] = {}

    def evaluate(cname: str, in_fusion: bool = False) -> Metrics:
        key = f"{cname}:{in_fusion}"
        if key in memo:
            return memo[key]
        memo[key] = Metrics()    # cycle guard
        met = Metrics()
        for ins in comps.get(cname, ()):
            if ins.op in _SKIP_OPS:
                continue
            res_bytes = ins.result_bytes()
            opnd_bytes = sum(_shape_bytes_of_ref(shapes, r)
                             for r in ins.operand_refs)
            coll = next((c for c in _COLLECTIVES
                         if ins.op == c or ins.op.startswith(c + "-")),
                        None)
            if coll:
                g = _group_size(ins.line, total_devices)
                factor = {"all-reduce": 2.0 * (g - 1) / max(g, 1),
                          "all-gather": float(g - 1),
                          "reduce-scatter": (g - 1) / max(g, 1),
                          "all-to-all": (g - 1) / max(g, 1),
                          "collective-permute": 1.0}[coll]
                s = met.collectives.setdefault(coll, CollectiveStat())
                s.count += 1
                s.operand_bytes += opnd_bytes
                s.wire_bytes += opnd_bytes * factor
                met.hbm_bytes += res_bytes + opnd_bytes
                continue
            if ins.op == "dot":
                if ins.operand_refs:
                    lhs = shapes.get(ins.operand_refs[0])
                    if lhs and not lhs[0].startswith("tuple:"):
                        lhs_dims = _dims_of(lhs[1])
                        mdims = re.search(
                            r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
                        contract = 1
                        if mdims:
                            for ix in mdims.group(1).split(","):
                                if ix and int(ix) < len(lhs_dims):
                                    contract *= lhs_dims[int(ix)]
                        out_elems = sum(
                            1 if not s else int(np_prod(s))
                            for _, s in ins.result_shapes)
                        met.dot_flops += 2.0 * out_elems * contract
                if not in_fusion:
                    met.hbm_bytes += res_bytes + opnd_bytes
                continue
            if ins.op in ("fusion", "call"):
                m = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", ins.line)
                # the fusion BOUNDARY is the real HBM traffic; fused
                # interiors stay in registers/VMEM (that is the point)
                if not in_fusion:
                    met.hbm_bytes += res_bytes + opnd_bytes
                if m and m.group(1) in comps:
                    met.add(evaluate(m.group(1), in_fusion=True), 1.0)
                continue
            if ins.op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.line)
                if mb and mb.group(1) in comps:
                    met.add(evaluate(mb.group(1), in_fusion=False),
                            _trip_count(ins, comps, shapes))
                continue
            if ins.op == "conditional":
                for key2 in ("true_computation", "false_computation"):
                    m = re.search(rf"{key2}=%?([\w.\-]+)", ins.line)
                    if m and m.group(1) in comps:
                        met.add(evaluate(m.group(1), in_fusion), 1.0)
                m = re.search(r"branch_computations=\{([^}]*)\}", ins.line)
                if m:
                    for ref in _REF_RE.findall(m.group(1)):
                        if ref in comps:
                            met.add(evaluate(ref, in_fusion), 1.0)
                continue
            if in_fusion:
                continue   # interior elementwise ops: no HBM traffic
            if ins.op == "dynamic-slice":
                met.hbm_bytes += 2 * res_bytes      # read slice + write
            elif ins.op == "dynamic-update-slice":
                # in-place window write: read+write the UPDATE region only
                upd = (_shape_bytes_of_ref(shapes, ins.operand_refs[1])
                       if len(ins.operand_refs) > 1 else res_bytes)
                met.hbm_bytes += 2 * upd
            elif ins.op == "gather":
                met.hbm_bytes += 2 * res_bytes
            elif ins.op == "scatter":
                upd = (_shape_bytes_of_ref(shapes, ins.operand_refs[-1])
                       if ins.operand_refs else res_bytes)
                met.hbm_bytes += 3 * upd
            elif ins.op == "broadcast":
                met.hbm_bytes += res_bytes
            else:
                met.hbm_bytes += res_bytes + opnd_bytes
        memo[key] = met
        return met

    m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
    entry = m.group(1) if m else (next(iter(comps)) if comps else None)
    if entry is None:
        return Metrics()
    if entry not in comps:
        entry = next(iter(comps))
    return evaluate(entry)


def np_prod(dims: str) -> int:
    n = 1
    for d in _dims_of(dims):
        n *= d
    return n


def analyze_compiled(compiled, total_devices: int) -> Metrics:
    return analyze_text(compiled.as_text(), total_devices)


def top_hbm_instructions(text: str, n: int = 20):
    """Perf-loop attribution: the n instructions contributing the most to
    the (trip-aware) HBM traffic estimate.  Returns
    [(bytes, trips, computation, op, name), ...] descending."""
    comps, shapes = _parse(text)
    # trip multiplier per computation (product along the call chain)
    mult: Dict[str, float] = {}
    fusion_interior: Dict[str, bool] = {}

    m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
    entry = m.group(1) if m else next(iter(comps), None)
    if entry is None:
        return []

    def walk(cname, k, interior):
        if mult.get(cname, 0) >= k and fusion_interior.get(cname, True) \
                <= interior:
            return
        mult[cname] = max(mult.get(cname, 0), k)
        fusion_interior[cname] = interior and fusion_interior.get(cname,
                                                                  True)
        for ins in comps.get(cname, ()):
            if ins.op in ("fusion", "call"):
                mm = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)",
                               ins.line)
                if mm and mm.group(1) in comps:
                    walk(mm.group(1), k, True)
            elif ins.op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.line)
                if mb and mb.group(1) in comps:
                    walk(mb.group(1), k * _trip_count(ins, comps, shapes),
                         interior)

    walk(entry, 1.0, False)
    out = []
    for cname, k in mult.items():
        if fusion_interior.get(cname):
            continue
        for ins in comps.get(cname, ()):
            if ins.op in _SKIP_OPS or ins.op in ("fusion", "call", "while",
                                                 "conditional"):
                if ins.op not in ("fusion", "call"):
                    continue
            res_bytes = ins.result_bytes()
            opnd_bytes = sum(_shape_bytes_of_ref(shapes, r)
                             for r in ins.operand_refs)
            if ins.op == "dynamic-slice":
                b = 2 * res_bytes
            elif ins.op == "dynamic-update-slice":
                upd = (_shape_bytes_of_ref(shapes, ins.operand_refs[1])
                       if len(ins.operand_refs) > 1 else res_bytes)
                b = 2 * upd
            elif ins.op == "gather":
                b = 2 * res_bytes
            elif ins.op == "scatter":
                upd = (_shape_bytes_of_ref(shapes, ins.operand_refs[-1])
                       if ins.operand_refs else res_bytes)
                b = 3 * upd
            elif ins.op == "broadcast":
                b = res_bytes
            else:
                b = res_bytes + opnd_bytes
            out.append((b * k, k, cname, ins.op, ins.name))
    out.sort(key=lambda t: -t[0])
    return out[:n]
