import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^^ MUST be the first two lines: jax locks the device count on first init.
# The dry-run (and ONLY the dry-run) sees 512 placeholder host devices so
# jax.make_mesh can build the production meshes.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell, print memory_analysis / cost_analysis, and record the roofline
terms (trip-count-aware, via repro.launch.hloanalysis).

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all --mesh both
  python -m repro.launch.dryrun ... --rules <name>   # sharding-rule preset

Results are cached as JSON under results/dryrun/<mesh>/<arch>__<shape>.json
(one file per cell) so the sweep is restartable.
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import costmodel
from repro.launch import hloanalysis
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models import layers, registry
from repro.models.config import SHAPES, shape_by_name
from repro.models.runtime import Runtime
from repro.optim import adamw
from repro.train import rules as rules_lib
from repro.train.steps import make_serve_step, make_train_step


def _shardings_for(specs, rt: Runtime):
    return layers.tree_shardings(specs, rt.rules_(), rt.mesh)


def _batch_shardings(batch_specs, rt: Runtime):
    from jax.sharding import NamedSharding, PartitionSpec as P
    import numpy as np
    target = rt.rules_().get("batch", ("pod", "data"))
    axes = target if isinstance(target, tuple) else (target,)
    batch_axes = tuple(a for a in axes if a in rt.mesh.shape)
    size = int(np.prod([rt.mesh.shape[a] for a in batch_axes]))

    def shard_one(s):
        if s.shape and s.shape[0] % size == 0:
            spec = P(batch_axes) + P(*([None] * (len(s.shape) - 1)))
        else:
            spec = P(*([None] * len(s.shape)))
        return NamedSharding(rt.mesh, spec)

    return jax.tree.map(shard_one, batch_specs)


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             rules_name: str = "baseline",
             gradsync: str = "gspmd",
             attn_impl: str = "xla",
             remat: str = "full") -> Dict[str, Any]:
    arch = registry.get(arch_name)
    shape = shape_by_name(shape_name)
    record: Dict[str, Any] = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "rules": rules_name, "gradsync": gradsync,
        "attn_impl": attn_impl, "remat": remat,
    }
    skip = arch.skip_reason(shape)
    if skip:
        record["status"] = "skipped"
        record["reason"] = skip
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(len(mesh.devices.flatten()))
    rules = rules_lib.get(rules_name, arch.cfg)
    batch_target = rules.get("batch", ("pod", "data"))
    batch_axes = batch_target if isinstance(batch_target, tuple) \
        else (batch_target,)
    batch_axes = tuple(a for a in batch_axes if a in mesh.shape)
    # largest prefix of the DP axes that divides the global batch
    # (e.g. full_dp wants 512-way but train_4k has batch 256 on the
    # 2-pod mesh -> fall back to ('pod','data') = 32-way)
    import numpy as np
    while batch_axes and shape.global_batch % int(
            np.prod([mesh.shape[a] for a in batch_axes])) != 0:
        batch_axes = batch_axes[:-1]
    rules = dict(rules, batch=batch_axes if len(batch_axes) != 1
                 else batch_axes[0])
    rt = Runtime(mesh=mesh, rules=rules, dp_axes=batch_axes,
                 gradsync=gradsync, attn_impl=attn_impl, remat=remat)
    t0 = time.time()

    specs = arch.param_specs()
    params_abs = layers.abstract_tree(specs)
    params_shard = _shardings_for(specs, rt)
    input_abs = arch.input_specs(shape)
    input_shard = _batch_shardings(input_abs, rt)

    if shape.kind == "train":
        opt_abs = adamw.abstract_state(params_abs)
        from jax.sharding import NamedSharding, PartitionSpec as P
        opt_shard = {
            "step": NamedSharding(mesh, P()),
            "master": params_shard, "m": params_shard, "v": params_shard,
        }
        step = make_train_step(arch, rt)
        jitted = jax.jit(step,
                         in_shardings=(params_shard, opt_shard,
                                       input_shard),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params_abs, opt_abs, input_abs)
    elif shape.kind == "prefill":
        step = make_serve_step(arch, rt, kind="prefill")
        jitted = jax.jit(step, in_shardings=(params_shard, input_shard))
        lowered = jitted.lower(params_abs, input_abs)
    else:  # decode
        cache_specs = arch.cache_specs(shape)
        cache_abs = layers.abstract_tree(cache_specs)
        cache_shard = _shardings_for(cache_specs, rt)
        step = make_serve_step(arch, rt, kind="decode")
        from jax.sharding import NamedSharding, PartitionSpec as P
        pos_shard = NamedSharding(mesh, P())
        jitted = jax.jit(step,
                         in_shardings=(params_shard, cache_shard,
                                       input_shard, pos_shard),
                         donate_argnums=(1,))
        pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
        lowered = jitted.lower(params_abs, cache_abs, input_abs, pos_abs)

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo_text = compiled.as_text()
    met = hloanalysis.analyze_text(hlo_text, n_chips)
    top = [
        {"gbytes": round(b / 1e9, 3), "trips": int(k), "comp": c[:40],
         "op": op, "name": nm[:50]}
        for b, k, c, op, nm in hloanalysis.top_hbm_instructions(
            hlo_text, 12)]

    # roofline terms (per system spec; quantities are per-device program,
    # so term = per-device quantity / per-chip peak)
    chip = costmodel.TPU_V5E
    compute_s = met.dot_flops / chip.peak_flops
    memory_s = met.hbm_bytes / chip.hbm_bw
    collective_s = met.collective_wire_bytes / chip.ici_bw
    dominant = max([("compute", compute_s), ("memory", memory_s),
                    ("collective", collective_s)], key=lambda kv: kv[1])[0]

    n_tokens = shape.global_batch * (shape.seq_len if not shape.is_decode
                                     else 1)
    n_params = arch.cfg.active_param_count()
    model_flops = (6 if shape.kind == "train" else 2) * n_params * n_tokens
    hlo_flops_global = met.dot_flops * n_chips

    record.update({
        "status": "ok",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes",
                                           None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None) or
            getattr(mem, "temp_size_in_bytes", None),
        },
        "xla_cost": {k: cost.get(k) for k in
                     ("flops", "bytes accessed") if k in cost},
        "hlo": met.to_dict(),
        "top_hbm": top,
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "dominant": dominant,
            "bound_s": max(compute_s, memory_s, collective_s),
            "model_flops": model_flops,
            "hlo_flops_global": hlo_flops_global,
            "useful_flop_frac": (model_flops / hlo_flops_global
                                 if hlo_flops_global else None),
            "tokens_per_s_bound": (n_tokens /
                                   max(compute_s, memory_s, collective_s)
                                   if max(compute_s, memory_s,
                                          collective_s) > 0 else None),
            "mfu_bound": (model_flops /
                          (max(compute_s, memory_s, collective_s)
                           * n_chips * chip.peak_flops)
                          if max(compute_s, memory_s, collective_s) > 0
                          else None),
        },
    })
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--rules", default="baseline")
    ap.add_argument("--gradsync", default="gspmd")
    ap.add_argument("--attn", default="xla",
                    choices=["xla", "chunked", "pallas"])
    ap.add_argument("--remat", default="full",
                    choices=["full", "dots", "none"])
    ap.add_argument("--variant", default=None,
                    help="subdirectory name for this configuration")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = registry.names() if args.arch == "all" else [args.arch]
    shapes = [s.name for s in SHAPES] if args.shape == "all" \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    for multi in meshes:
        mesh_name = "pod2x16x16" if multi else "pod16x16"
        outdir = Path(args.out) / mesh_name
        if args.variant:
            outdir = outdir / args.variant
        elif (args.rules, args.gradsync, args.attn, args.remat) != \
                ("baseline", "gspmd", "xla", "full"):
            outdir = outdir / f"{args.rules}__{args.gradsync}__" \
                f"{args.attn}__{args.remat}"
        outdir.mkdir(parents=True, exist_ok=True)
        for arch in archs:
            for shape in shapes:
                path = outdir / f"{arch}__{shape}.json"
                if path.exists() and not args.force:
                    print(f"[cached] {mesh_name} {arch} {shape}")
                    continue
                print(f"[dryrun] {mesh_name} {arch} {shape} ...",
                      flush=True)
                try:
                    rec = run_cell(arch, shape, multi, args.rules,
                                   args.gradsync, args.attn, args.remat)
                except Exception as e:  # record failures — they are bugs
                    rec = {"arch": arch, "shape": shape,
                           "mesh": mesh_name, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                path.write_text(json.dumps(rec, indent=2, default=str))
                status = rec.get("status")
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" dominant={r['dominant']}"
                             f" bound={r['bound_s']*1e3:.1f}ms"
                             f" compile={rec['compile_s']:.0f}s")
                elif status == "error":
                    extra = " " + rec["error"][:120]
                print(f"[{status}] {mesh_name} {arch} {shape}{extra}",
                      flush=True)


if __name__ == "__main__":
    main()
