"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Spins up the continuous-batching engine on a reduced config, feeds it a
synthetic request stream, and reports throughput/latency.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.models import layers, registry
from repro.models.runtime import Runtime
from repro.serve.engine import EngineConfig, Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = registry.get(args.arch)
    cfg = arch.cfg.reduced()
    params = layers.init_tree(registry.param_specs(cfg),
                              jax.random.key(args.seed))
    engine = ServeEngine(args.arch, params, cfg,
                         EngineConfig(max_batch=args.max_batch,
                                      max_len=128), Runtime())
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for i in range(args.requests):
        engine.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=args.prompt_len,
                                dtype=np.int32),
            max_new_tokens=args.max_new))
    done = engine.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.tokens_out) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, {engine.decode_steps} decode steps, "
          f"{engine.rounds} rounds)")
    lat = [r.finished_at - r.submitted_at for r in done]
    print(f"latency mean {np.mean(lat)*1e3:.0f}ms p99 "
          f"{np.percentile(lat, 99)*1e3:.0f}ms")


if __name__ == "__main__":
    main()
