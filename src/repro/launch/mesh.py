"""Production mesh construction (a FUNCTION — importing this module never
touches jax device state)."""

from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def make_smoke_mesh() -> jax.sharding.Mesh:
    """Whatever devices exist, as a 1-D data mesh (CPU tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
