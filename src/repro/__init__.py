"""repro — Spindle (RDMA atomic multicast optimizations) as a multi-pod
JAX training/serving framework.  See README.md and DESIGN.md."""

import os as _os


def enable_compilation_cache(path: str) -> None:
    """Point JAX's persistent compilation cache at ``path``.

    Every compile-once program in the repo (the stacked scan/stream
    programs, the fused serve program, jitted decode steps) is re-traced
    per PROCESS; across processes the trace is cheap but the XLA compile
    is not.  With the cache on, a cold process deserializes previously
    compiled executables from disk instead of recompiling — the
    cold-start delta is measured by ``benchmarks/hotpath.py``
    (``compile_cache`` row in BENCH_hotpath.json).

    Zero thresholds so even the sub-second CPU compiles of the test
    shapes are cached — the default thresholds only persist compiles
    over a second, which on the benchmark shapes would cache nothing.
    """
    import jax

    jax.config.update("jax_compilation_cache_dir", path)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)


# Opt-in via environment so every entry point (pytest, benchmarks,
# subprocesses) inherits it without code changes: REPRO_COMPILATION_CACHE
# names the cache directory; unset/empty leaves JAX's default (off).
_cache_dir = _os.environ.get("REPRO_COMPILATION_CACHE")
if _cache_dir:
    enable_compilation_cache(_cache_dir)
del _os, _cache_dir
