"""repro — Spindle (RDMA atomic multicast optimizations) as a multi-pod
JAX training/serving framework.  See README.md and DESIGN.md."""


def enable_compilation_cache(path: str) -> None:
    """Point JAX's persistent compilation cache at ``path``.

    Every compile-once program in the repo (the stacked scan/stream
    programs, the fused serve program, jitted decode steps) is re-traced
    per PROCESS; across processes the trace is cheap but the XLA compile
    is not.  With the cache on, a cold process deserializes previously
    compiled executables from disk instead of recompiling — the
    cold-start delta is measured by ``benchmarks/hotpath.py``
    (``compile_cache`` row in BENCH_hotpath.json).

    The directory is created if missing (XLA's cache writer does not
    mkdir for you; a nonexistent dir silently caches nothing).

    Zero thresholds so even the sub-second CPU compiles of the test
    shapes are cached — the default thresholds only persist compiles
    over a second, which on the benchmark shapes would cache nothing.
    """
    import os

    import jax

    os.makedirs(path, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", path)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)


def _enable_cache_from_env() -> None:
    """Opt-in via environment so every entry point (pytest, benchmarks,
    subprocesses) inherits the cache without code changes:
    ``REPRO_COMPILATION_CACHE`` names the cache directory; unset/empty
    leaves JAX's default (off).

    The env var is read ONCE, at ``import repro`` — setting it after
    this module (or jax's cache config) is already loaded cannot take
    effect, and an explicit ``jax_compilation_cache_dir`` someone
    already configured wins over the env var.  Both used to be silent;
    now the losing env var warns once so a "why is nothing cached?" hunt
    ends here instead of in XLA."""
    import os

    cache_dir = os.environ.get("REPRO_COMPILATION_CACHE")
    if not cache_dir:
        return
    import jax

    configured = jax.config.jax_compilation_cache_dir
    if configured and configured != cache_dir:
        import warnings

        warnings.warn(
            "REPRO_COMPILATION_CACHE=%r ignored: jax was already "
            "configured with jax_compilation_cache_dir=%r (explicit "
            "configuration wins; unset one of them)"
            % (cache_dir, configured), RuntimeWarning, stacklevel=2)
        return
    enable_compilation_cache(cache_dir)


_enable_cache_from_env()
