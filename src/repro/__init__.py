"""repro — Spindle (RDMA atomic multicast optimizations) as a multi-pod
JAX training/serving framework.  See README.md and DESIGN.md."""
