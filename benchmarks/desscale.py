"""Two-phase DES scale-out benchmark (DESIGN.md Sec. 12).

Times the same fleet-scale scenario three ways at N in {64, 256, 1024}:

* ``legacy_s``  — the single-phase ``des-loop`` event loop
  (:class:`repro.core.simulator.Simulator`), the pre-split baseline.
* ``phase1_s``  — :func:`repro.core.desgraph.simulate`, the slimmed
  event-level pass that assigns timestamps and emits the compact
  event/delivery graph (merged per-(subgroup, source) wire streams).
* ``phase2_s``  — :func:`repro.core.desreplay.replay`, the vectorized
  numpy reconstruction of delivery logs, costs and the
  :class:`~repro.core.simulator.SimResult` from that graph.

``two_phase_s`` = phase1 + phase2 is what ``backend="des"`` costs;
``speedup`` = legacy / two_phase.  Every point also asserts the
two-phase :class:`SimResult` and per-member delivery sequences are
BIT-IDENTICAL to the legacy loop's — the differential contract the
split lives under.  Legacy and two-phase timings are interleaved within
each repeat (best-of) so box noise can't skew the ratio.

Writes ``BENCH_desscale.json`` at the repo root (committed).  ``--smoke``
runs only the CI gate — bit-identity vs ``des-loop`` at N=64 and
speedup >= 5x at N=256 — and FAILS (exit 1) on either; this is the CI
``des-scale`` job.

Run:  PYTHONPATH=src python benchmarks/desscale.py [--smoke] [--json PATH]
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

from repro.core import desgraph, desreplay
from repro.core import simulator as sim
from repro.core.group import DESLoopBackend, Group, single_group

ROOT = Path(__file__).resolve().parent.parent
BENCH_PATH = ROOT / "BENCH_desscale.json"

# Steady-state fleet points: enough in-flight traffic per sender that the
# wire dominates (the regime the vectorized replay targets).  The 1024
# point backs off n_messages so the legacy loop stays tractable; the
# 4096-node point lives in tests/test_des_scale.py under ``-m soak``
# (conformance, not wall clock).
SCALES = (
    dict(n=64, senders=8, msgs=32, window=32),
    dict(n=256, senders=8, msgs=32, window=32),
    dict(n=1024, senders=8, msgs=4, window=16),
)
SPEEDUP_FLOOR = 5.0                  # gated at N=256, the mid-scale point
GATE_N = 256
IDENTITY_N = 64                      # the smoke bit-identity point


def _cfg(n, senders, msgs, window):
    return single_group(n, n_senders=senders, msg_size=4096,
                        window=window, n_messages=msgs)


def _sim_cfg(cfg):
    g = Group(cfg)
    counts = {i: g.send_counts(i, cfg)
              for i in range(len(cfg.subgroups))}
    return DESLoopBackend._lower(cfg, counts)


def _eq(a, b):
    """Bit-exact structural equality over results (NaN == NaN)."""
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        a, b = np.asarray(a), np.asarray(b)
        return (a.shape == b.shape and a.dtype == b.dtype
                and bool(np.array_equal(a, b, equal_nan=(
                    a.dtype.kind == "f"))))
    if isinstance(a, dict):
        return (isinstance(b, dict) and set(a) == set(b)
                and all(_eq(a[k], b[k]) for k in a))
    if isinstance(a, (list, tuple)):
        return (isinstance(b, (list, tuple)) and len(a) == len(b)
                and all(_eq(x, y) for x, y in zip(a, b)))
    if isinstance(a, float) and isinstance(b, float):
        return (a != a and b != b) or a == b
    return a == b


def _log_digest(logs):
    return {gid: {int(n): log.sequence(n)
                  for n in log.delivered_seq}
            for gid, log in logs.items()}


def bench_point(shape, repeats=3):
    """One scale point: interleaved best-of timings plus bit-identity."""
    scfg = _sim_cfg(_cfg(**shape))
    legacy = p1 = p2 = float("inf")
    res_legacy = res_two = legacy_logs = two_logs = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        simulator = sim.Simulator(scfg)
        res_legacy = simulator.run()
        legacy = min(legacy, time.perf_counter() - t0)

        t0 = time.perf_counter()
        graph = desgraph.simulate(scfg)
        p1 = min(p1, time.perf_counter() - t0)
        t0 = time.perf_counter()
        res_two = desreplay.replay(graph)
        p2 = min(p2, time.perf_counter() - t0)
    from repro.core.group import _des_logs
    legacy_logs = _des_logs(simulator.groups)
    two_logs = _des_logs(graph.groups)
    identical = (_eq(vars(res_legacy), vars(res_two))
                 and _eq(_log_digest(legacy_logs), _log_digest(two_logs)))
    two_phase = p1 + p2
    return {
        "n_nodes": shape["n"],
        "senders": shape["senders"],
        "n_messages": shape["msgs"],
        "window": shape["window"],
        "legacy_s": round(legacy, 4),
        "phase1_s": round(p1, 4),
        "phase2_s": round(p2, 4),
        "two_phase_s": round(two_phase, 4),
        "speedup": round(legacy / two_phase, 2),
        "bit_identical": bool(identical),
        "delivered_app_msgs": int(res_two.delivered_app_msgs),
        "stalled": bool(res_two.stalled),
    }


def smoke_gate() -> int:
    """The CI ``des-scale`` gate: N=64 bit-identity + N=256 >= 5x."""
    failures = []
    small = bench_point(next(s for s in SCALES if s["n"] == IDENTITY_N),
                        repeats=2)
    print(f"N={IDENTITY_N}: bit_identical={small['bit_identical']} "
          f"(legacy {small['legacy_s']}s, two-phase "
          f"{small['two_phase_s']}s)")
    if not small["bit_identical"]:
        failures.append(f"n{IDENTITY_N}.bit_identical")
    if small["stalled"]:
        failures.append(f"n{IDENTITY_N}.stalled")
    mid = bench_point(next(s for s in SCALES if s["n"] == GATE_N),
                      repeats=2)
    status = "OK" if mid["speedup"] >= SPEEDUP_FLOOR else "REGRESSION"
    print(f"N={GATE_N}: speedup {mid['speedup']}x (floor "
          f"{SPEEDUP_FLOOR}x; legacy {mid['legacy_s']}s, phase1 "
          f"{mid['phase1_s']}s, phase2 {mid['phase2_s']}s) {status}")
    if mid["speedup"] < SPEEDUP_FLOOR:
        failures.append(f"n{GATE_N}.speedup")
    if not mid["bit_identical"]:
        failures.append(f"n{GATE_N}.bit_identical")
    if failures:
        print(f"des-scale smoke FAILED: {failures}")
        return 1
    print("des-scale smoke passed")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: N=64 bit-identity + N=256 >= 5x")
    ap.add_argument("--json", type=Path, default=BENCH_PATH)
    args = ap.parse_args()
    if args.smoke:
        return smoke_gate()
    points = [bench_point(s) for s in SCALES]
    record = {
        "speedup_floor_at_n256": SPEEDUP_FLOOR,
        "scales": points,
        "scenario": {"msg_size": 4096, "points": [dict(s) for s in SCALES]},
    }
    args.json.write_text(json.dumps(record, indent=1) + "\n")
    print(json.dumps(record, indent=1))
    print(f"-> {args.json}")
    gate = next(p for p in points if p["n_nodes"] == GATE_N)
    ok = (all(p["bit_identical"] and not p["stalled"] for p in points)
          and gate["speedup"] >= SPEEDUP_FLOOR)
    print("acceptance:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
