"""Benchmark harness — one function per paper table/figure, driven through
the unified Group API (every scenario is a GroupConfig run on the ``des``
backend; the ``backends`` bench runs one scenario across all three).

Prints ``name,us_per_call,derived`` CSV (us_per_call = simulated mean
per-message delivery interval at one node; derived = the figure's headline
metric, GB/s unless noted).  Full records land in results/bench/*.json.

Run:  PYTHONPATH=src python -m benchmarks.run [--only fig3 ...]
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.core import costmodel, dds, simulator as sim
from repro.core.group import Group, RunReport, single_group

RESULTS = Path("results/bench")
_ROWS = []
_CACHE = {}


def emit(name: str, us_per_call: float, derived: float, **extra):
    _ROWS.append({"name": name, "us_per_call": round(us_per_call, 3),
                  "derived": round(derived, 4), **extra})
    print(f"{name},{us_per_call:.3f},{derived:.4f}", flush=True)


def run_group(make_group, key: str) -> RunReport:
    """Run ``make_group()`` on the des backend of the Group API (cached)."""
    if key not in _CACHE:
        _CACHE[key] = make_group().run(backend="des")
    return _CACHE[key]


def run_sim(cfg: sim.SimConfig, key: str) -> RunReport:
    return run_group(lambda: Group.from_sim_config(cfg), key)


def _per_msg_us(r: RunReport) -> float:
    if r.delivered_app_msgs == 0:
        return float("inf")
    per_node = r.delivered_app_msgs / max(len(r.per_node_throughput), 1)
    return r.duration_us / max(per_node, 1)


def _flags(**kw) -> sim.SpindleFlags:
    return sim.SpindleFlags(**kw)


BASE_N = dict(spindle=1200, baseline=250)


def _single(n, *, senders=None, flags=None, msgs=None, **kw):
    label = "baseline" if flags and not flags.batch_send and \
        not flags.batch_receive else "spindle"
    flags = flags if flags is not None else sim.SpindleFlags.spindle()
    msgs = msgs if msgs is not None else BASE_N[label]
    return sim.single_subgroup(n, n_senders=senders, n_messages=msgs,
                               flags=flags, **kw)


# ---------------------------------------------------------------------------

def fig1_latency_curve():
    """RDMA write latency vs size (cost-model calibration, Fig. 1)."""
    for size in (1, 128, 1024, 4096, 10240):
        lat = costmodel.RDMA_CX6.wire_latency(min(size, 4096)) + \
            costmodel.RDMA_CX6.serialization(size)
        emit(f"fig1/latency_{size}B", lat, lat)


def fig3_single_subgroup():
    """Single subgroup continuous sending, 10KB (Fig. 3): baseline vs
    opportunistic batching across group sizes and sender fractions."""
    for n in (2, 4, 8, 11, 16):
        for mode, senders in (("all", None), ("half", max(n // 2, 1)),
                              ("one", 1)):
            r = run_sim(_single(n, senders=senders),
                        f"spin_{n}_{mode}")
            emit(f"fig3/spindle_n{n}_{mode}", _per_msg_us(r),
                 r.throughput_GBps)
    for n in (2, 8, 16):
        r = run_sim(_single(n, flags=sim.SpindleFlags.baseline()),
                    f"base_{n}_all")
        emit(f"fig3/baseline_n{n}_all", _per_msg_us(r),
             r.throughput_GBps)


def fig4_delivery_rate():
    """Messages delivered per second across small sizes (Fig. 4)."""
    for size in (1, 128, 1024, 10240):
        r = run_sim(_single(16, msg_size=size, msgs=800),
                    f"size_{size}")
        rate = r.delivered_app_msgs / max(len(r.per_node_throughput), 1) \
            / max(r.duration_us, 1e-9) * 1e6
        emit(f"fig4/rate_{size}B", _per_msg_us(r), rate,
             throughput_GBps=r.throughput_GBps)


def fig5_incremental_stages():
    """Batching applied to successively more stages (Fig. 5), n=16."""
    stages = [
        ("baseline", sim.SpindleFlags.baseline()),
        ("+delivery", sim.SpindleFlags(
            batch_receive=False, batch_send=False, null_send=False,
            early_lock_release=False, batched_upcall=True)),
        ("+receive", sim.SpindleFlags(
            batch_send=False, null_send=False, early_lock_release=False)),
        ("+send", sim.SpindleFlags(null_send=False,
                                   early_lock_release=False)),
        ("+nulls", sim.SpindleFlags(early_lock_release=False)),
        ("+locks", sim.SpindleFlags.spindle()),
    ]
    for name, flags in stages:
        msgs = 250 if name == "baseline" else 800
        r = run_sim(_single(16, flags=flags, msgs=msgs), f"stage_{name}")
        emit(f"fig5/{name}", r.mean_latency_us, r.throughput_GBps,
             latency_us=r.mean_latency_us)


def fig6_window_size():
    """Ring-buffer window sweep (Fig. 6), all senders, n=16 — the whole
    grid executes as ONE compiled batched program (Group.run_batch on the
    graph substrate) instead of 5 sequential runs."""
    wins = (5, 20, 100, 500, 1000)
    g = Group(single_group(16, msg_size=10240, window=100, n_messages=800))
    for w, r in zip(wins, g.run_batch(backend="graph", windows=list(wins))):
        emit(f"fig6/w{w}", _per_msg_us(r), r.throughput_GBps)


def fig7_batch_histograms():
    """Batch-size distributions per stage (Fig. 7), n=16 all senders."""
    r = run_sim(_single(16), "spin_16_all")
    for stage, data in (("send", r.send_batches),
                        ("receive", r.recv_batches),
                        ("delivery", r.deliv_batches)):
        arr = np.asarray(data)
        emit(f"fig7/{stage}_mean", float(arr.mean()), float(arr.mean()),
             p50=float(np.percentile(arr, 50)),
             p95=float(np.percentile(arr, 95)))


def _multi_group(n_nodes, n_groups, active, flags, msgs):
    groups = []
    for g in range(n_groups):
        groups.append(sim.SubgroupSpec(
            members=tuple(range(n_nodes)), senders=tuple(range(n_nodes)),
            n_messages=msgs if (g == 0 or active == "all") else 0))
    return sim.SimConfig(n_nodes=n_nodes, subgroups=tuple(groups),
                         flags=flags)


def fig9_single_active_subgroup():
    """1 active subgroup among k overlapping (Figs. 8/9)."""
    for k in (1, 2, 5, 10, 20):
        r = run_sim(_multi_group(16, k, "one",
                                 sim.SpindleFlags.spindle(), 700),
                    f"act1_spin_{k}")
        emit(f"fig9/spindle_groups{k}", _per_msg_us(r),
             r.throughput_GBps)
    for k in (1, 2, 5, 10):
        r = run_sim(_multi_group(16, k, "one",
                                 sim.SpindleFlags.baseline(), 120),
                    f"act1_base_{k}")
        emit(f"fig8/baseline_groups{k}", _per_msg_us(r),
             r.throughput_GBps)


def fig10_delayed_sender():
    """Null-sends under sender delays (Fig. 10)."""
    cases = [("one_1us", 1, 1.0), ("one_100us", 1, 100.0),
             ("one_inf", 1, 1e9), ("half_1us", 8, 1.0),
             ("half_100us", 8, 100.0), ("half_inf", 8, 1e9)]
    for name, k, delay in cases:
        pats = tuple(((0, i), sim.SenderPattern(inter_send_delay_us=delay))
                     for i in range(k))
        cfg = sim.single_subgroup(
            16, n_messages=4000, patterns=pats,
            target_delivered=(16 - k) * 700)
        r = run_sim(cfg, f"delay_{name}")
        emit(f"fig10/{name}", _per_msg_us(r), r.throughput_GBps,
             nulls=r.nulls_sent)


def fig11_null_overhead():
    """Null-send overhead under continuous sending (Fig. 11).  Per group
    size the on/off pair runs as ONE batched program (Group.run_batch over
    the null_send flag grid on the graph substrate)."""
    for n in (2, 4, 8, 16):
        g = Group(single_group(n, msg_size=10240, window=100,
                               n_messages=1200))
        r_on, r_off = g.run_batch(backend="graph",
                                  null_send=[True, False])
        emit(f"fig11/nulls_on_n{n}", _per_msg_us(r_on),
             r_on.throughput_GBps, nulls=r_on.nulls_sent)
        emit(f"fig11/nulls_off_n{n}", _per_msg_us(r_off),
             r_off.throughput_GBps)


def fig12_thread_sync():
    """Lock release before RDMA posts (Fig. 12)."""
    for n in (4, 8, 16):
        r_on = run_sim(_single(n), f"spin_{n}_all")
        r_off = run_sim(_single(n, flags=_flags(early_lock_release=False),
                                msgs=1200), f"nolock_{n}")
        emit(f"fig12/locks_early_n{n}", _per_msg_us(r_on),
             r_on.throughput_GBps)
        emit(f"fig12/locks_held_n{n}", _per_msg_us(r_off),
             r_off.throughput_GBps)


def fig13_multi_active():
    """Multiple active subgroups with all optimizations (Fig. 13)."""
    for k in (1, 2, 5):
        r = run_sim(_multi_group(16, k, "all",
                                 sim.SpindleFlags.spindle(), 400),
                    f"actall_spin_{k}")
        emit(f"fig13/spindle_active{k}", _per_msg_us(r),
             r.throughput_GBps)


def fig14_memcpy_curve():
    """Host memcpy latency vs size (Fig. 14 calibration)."""
    for size in (128, 1024, 10240, 102400):
        lat = costmodel.HOST_X86.memcpy(size)
        emit(f"fig14/memcpy_{size}B", lat, size / max(lat, 1e-9) / 1e3)


def fig15_memcpy_delivery():
    """memcpy in send + delivery paths (Fig. 15), n=16."""
    for mode, flags in (
            ("zero_copy", sim.SpindleFlags.spindle()),
            ("memcpy", _flags(memcpy_delivery=True, memcpy_send=True))):
        r = run_sim(_single(16, flags=flags, msgs=800), f"memcpy_{mode}")
        emit(f"fig15/{mode}", _per_msg_us(r), r.throughput_GBps)


def fig16_final():
    """Final throughput + latency, all optimizations (Figs. 16/17)."""
    for n in (2, 8, 16):
        for mode, senders in (("all", None), ("half", max(n // 2, 1)),
                              ("one", 1)):
            r = run_sim(_single(n, senders=senders),
                        f"spin_{n}_{mode}")
            emit(f"fig16/n{n}_{mode}", r.mean_latency_us,
                 r.throughput_GBps, p99_latency_us=r.p99_latency_us)


def fig18_dds_qos():
    """DDS QoS levels, baseline vs Spindle (Fig. 18)."""
    for qos in dds.QoS:
        for spindle in (False, True):
            domain = dds.single_topic_domain(16, 15, qos=qos)
            r = run_group(lambda: domain.group(
                samples_per_publisher=150 if not spindle else 800,
                spindle=spindle), f"dds_{qos.value}_{spindle}")
            tag = "spindle" if spindle else "baseline"
            emit(f"fig18/{qos.value}_{tag}", _per_msg_us(r),
                 r.throughput_GBps)


def backends_cross_substrate():
    """One GroupConfig scenario on all three protocol backends — the
    unified-API like-for-like comparison (des vs graph vs pallas).  The
    graph/pallas points go through the batched execution path
    (Group.run_batch), which is asserted to reproduce Group.run exactly."""
    cfg = single_group(8, n_senders=4, msg_size=4096, window=32,
                       n_messages=60)
    seqs = {}
    g = Group(cfg)
    r = g.run(backend="des")
    seqs["des"] = g.subgroup(0).delivered(0)
    emit("backends/des", _per_msg_us(r), r.throughput_GBps,
         rdma_writes=r.rdma_writes, nulls=r.nulls_sent,
         delivered_app=r.delivered_app_msgs, stalled=r.stalled)
    for backend in ("graph", "pallas"):
        g = Group(cfg)
        (r,) = g.run_batch(backend=backend, windows=[32])
        log = r.extras["delivery_logs"][0]
        seqs[backend] = log.sequence(0)
        r_single = Group(cfg).run(backend=backend)
        assert r_single.delivered_app_msgs == r.delivered_app_msgs, backend
        emit(f"backends/{backend}", _per_msg_us(r), r.throughput_GBps,
             rdma_writes=r.rdma_writes, nulls=r.nulls_sent,
             delivered_app=r.delivered_app_msgs, stalled=r.stalled)
    agree = seqs["des"] == seqs["graph"] == seqs["pallas"]
    emit("backends/delivery_order_identical", 0.0, float(agree))
    assert agree, "backends disagree on the delivered total order"


def sec35_upcall_delay():
    """Sensitivity to delivery-upcall delay (Sec. 3.5)."""
    base = None
    for delay in (0.0, 1.0, 100.0, 1000.0):
        flags = _flags(batched_upcall=False)
        cfg = sim.single_subgroup(16, n_messages=300, flags=flags,
                                  upcall_extra_us=delay)
        r = run_sim(cfg, f"upcall_{delay}")
        if base is None:
            base = r.throughput_GBps
        emit(f"sec35/upcall_{int(delay)}us", _per_msg_us(r),
             r.throughput_GBps,
             frac_of_no_delay=r.throughput_GBps / max(base, 1e-9))


def gradsync_collectives():
    """Training-plane analogue: collectives per step for per-tensor vs
    fused-bucket vs compressed gradient multicast (analytic, from the
    bucket plan of the examples/train_lm 100M model)."""
    import jax
    import sys
    sys.path.insert(0, ".")
    from examples.train_lm import model_100m
    from repro.core import gradsync
    from repro.models import registry as reg
    from repro.models import layers as L

    cfg = model_100m()
    specs = reg.param_specs(cfg)
    abstract = L.abstract_tree(specs)
    n_tensors = len(jax.tree.leaves(abstract))
    total_bytes = float(sum(
        np.prod(l.shape, dtype=np.int64) * 4
        for l in jax.tree.leaves(abstract)))
    plan = gradsync.make_plan(abstract, target_bytes=32 << 20)
    g = 16  # DP degree
    ar = lambda b: 2 * (g - 1) / g * b  # noqa: E731  ring all-reduce
    compressed_wire = (g - 1) / g * total_bytes + \
        (g - 1) * (total_bytes / 4 / g)   # RS f32 + AG int8
    emit("gradsync/per_tensor", float(n_tensors), ar(total_bytes) / 1e9,
         collectives=n_tensors)
    emit("gradsync/fused", float(plan.n_buckets), ar(total_bytes) / 1e9,
         collectives=plan.n_buckets)
    emit("gradsync/compressed", float(plan.n_buckets),
         compressed_wire / 1e9, collectives=2 * plan.n_buckets)


BENCHES = {
    "fig1": fig1_latency_curve,
    "fig3": fig3_single_subgroup,
    "fig4": fig4_delivery_rate,
    "fig5": fig5_incremental_stages,
    "fig6": fig6_window_size,
    "fig7": fig7_batch_histograms,
    "fig9": fig9_single_active_subgroup,
    "fig10": fig10_delayed_sender,
    "fig11": fig11_null_overhead,
    "fig12": fig12_thread_sync,
    "fig13": fig13_multi_active,
    "fig14": fig14_memcpy_curve,
    "fig15": fig15_memcpy_delivery,
    "fig16": fig16_final,
    "fig18": fig18_dds_qos,
    "sec35": sec35_upcall_delay,
    "gradsync": gradsync_collectives,
    "backends": backends_cross_substrate,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args()
    names = args.only if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in names:
        BENCHES[name]()
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "bench.json").write_text(json.dumps(_ROWS, indent=1))
    print(f"# {len(_ROWS)} rows in {time.time()-t0:.0f}s "
          f"-> {RESULTS/'bench.json'}")


if __name__ == "__main__":
    main()
