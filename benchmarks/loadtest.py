"""Open-loop load benchmark — the offered-load -> (p99, goodput) curve of
the serve-plane substrate under the workload plane (DESIGN.md Sec. 10).

Unlike benchmarks/hotpath.py (wall clocks of the compiled programs) this
commits the PROTOCOL-TIME shape of the system under open-loop traffic:

* ``curve``  — a sweep of offered-load points (per-sender Poisson rate x
  scale), each a warmup+measure profile run through
  :func:`repro.load.run_profile` against a fresh group with a
  ``WindowSlack`` admission policy.  Per point: offered vs goodput
  (msgs/round), p50/p99/p999 latency in rounds and simulated us, shed
  count, queue/backlog highwater.  The sweep deliberately crosses
  saturation (~window/3 msgs per sender-round): past it, goodput must
  plateau while offered keeps climbing, shed must go positive, and p99
  must stay BOUNDED — that separation is the honesty constraint; a
  closed-loop harness could never show it.
* ``ramp``   — one staged_ramp profile (warmup -> steps -> overload) run
  end-to-end, the per-stage stats as a single LoadReport.
* ``one_program`` / warm trace deltas — the whole sweep rides ONE
  compiled one-round program per group shape: the cold run appends <=1
  TRACE_EVENTS entry, a second identical run appends 0.
* ``fused_serve`` — the serve-plane open-loop sweep run BOTH ways at
  each offered-load point: the per-round host loop vs the fused
  device-resident program (``run_profile(..., fused=True)``).  The two
  LoadReports must be byte-identical JSON (the fused path is an
  execution strategy, not a different system), the fused run must
  actually fuse with ``host_hops == 0``, and its wall-clock goodput
  (delivered requests per second, same runner, best-of-2) must be
  >= 2x the per-round loop's — the Spindle fused-dispatch claim at the
  committed loadtest shape, held by the smoke gate.

All latency/goodput numbers are deterministic (seeded arrivals, simulated
time), so the committed baseline regresses exactly; only ``*_wall_s`` is
machine-dependent.  Writes ``BENCH_loadtest.json`` at the repo root
(committed).  ``--smoke`` runs a 3-point sweep plus one fused-serve
point and FAILS (exit 1) on regression vs the committed baseline's
``smoke`` section: p99 blowup, goodput collapse, a vanished shed
signal, unbounded queues, extra compiles, a fused-serve run that fell
back / took host hops / diverged from the per-round loop, or a fused
speedup under the 2x floor; this is the CI ``loadtest-smoke`` gate.

Run:  PYTHONPATH=src python benchmarks/loadtest.py [--smoke] [--json PATH]
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from repro.api import Group, single_group, trace_snapshot
from repro.load import (Poisson, Profile, Stage, WindowSlack, run_profile,
                        staged_ramp)

ROOT = Path(__file__).resolve().parent.parent
BENCH_PATH = ROOT / "BENCH_loadtest.json"

# offered per sender-round = rate * scale; saturation ~ window/3 = 1.33,
# so both shapes end well past it (FULL: 1.6, 3.2; SMOKE: 3.0).
FULL = dict(n=5, senders=3, window=4, rate=0.4, warmup=30, measure=60,
            scales=(0.5, 1.0, 2.0, 4.0, 8.0),
            inflight_limit=8, queue_cap=32,
            ramp=dict(warmup=40, steps=(1.0, 2.0), rounds_per_stage=60,
                      overload=8.0))
SMOKE = dict(n=4, senders=2, window=4, rate=0.5, warmup=8, measure=16,
             scales=(1.0, 2.5, 6.0),
             inflight_limit=8, queue_cap=16,
             ramp=dict(warmup=10, steps=(1.0,), rounds_per_stage=16,
                       overload=6.0))

# serve-plane open-loop shapes: arrival lanes are KV slots, admission is
# ServeAdmission (queue_cap tail-drop per replica).  FULL crosses serve
# saturation (slots per replica bound concurrent decode); SMOKE is one
# past-saturation point, enough rounds that the per-round loop's
# dispatch overhead dominates — that is what the fused 2x gate measures.
SERVE_FULL = dict(replicas=2, slots=2, prompt=3, new_tokens=4, rate=0.5,
                  warmup=6, measure=24, scales=(0.5, 1.5, 3.0),
                  queue_cap=6)
SERVE_SMOKE = dict(replicas=2, slots=2, prompt=3, new_tokens=4, rate=0.5,
                   warmup=4, measure=12, scales=(1.5,), queue_cap=4)
FUSED_SPEEDUP_FLOOR = 2.0

# --smoke gates vs the committed baseline.  The protocol-time metrics are
# seeded-deterministic, so these factors only have to absorb legitimate
# protocol/policy tuning, not machine jitter; wall clock gets the usual
# 3x + slack treatment.
P99_FACTOR, P99_SLACK_ROUNDS = 1.5, 2.0
GOODPUT_FACTOR = 0.7
WALL_FACTOR, WALL_SLACK_S = 3.0, 0.1


def _group(shape):
    return Group(single_group(shape["n"], n_senders=shape["senders"],
                              msg_size=4096, window=shape["window"],
                              n_messages=0))


def _policy(shape):
    return WindowSlack(inflight_limit=shape["inflight_limit"],
                       queue_cap=shape["queue_cap"])


def _point(shape, scale, backend="graph"):
    """One offered-load point: warmup + measure stages at `scale`."""
    prof = Profile(arrivals=Poisson(rate=shape["rate"]), seed=7, stages=(
        Stage("warmup", shape["warmup"], scale),
        Stage("measure", shape["measure"], scale)))
    rep = run_profile(_group(shape), prof, _policy(shape),
                      backend=backend)
    st = rep.stage("measure")
    return {
        "scale": scale,
        "offered_per_round": st.offered_per_round,
        "goodput_per_round": st.goodput_per_round,
        "p50_rounds": st.p50_rounds,
        "p99_rounds": st.p99_rounds,
        "p999_rounds": st.p999_rounds,
        "p99_us": st.p99_us,
        "shed": st.shed,
        "max_queue_depth": st.max_queue_depth,
        "max_stream_backlog": st.max_stream_backlog,
    }


def bench_curve(shape, backend="graph"):
    """The offered-load sweep + the one-program trace accounting."""
    n0 = len(trace_snapshot())
    t0 = time.perf_counter()
    points = [_point(shape, s, backend) for s in shape["scales"]]
    cold_wall = time.perf_counter() - t0
    traces_cold = len(trace_snapshot()) - n0
    # second identical sweep: every stage of every point rides the cached
    # program — zero new traces, and the warm wall clock is the real cost
    n0 = len(trace_snapshot())
    t0 = time.perf_counter()
    for s in shape["scales"]:
        _point(shape, s, backend)
    warm_wall = time.perf_counter() - t0
    traces_warm = len(trace_snapshot()) - n0
    sat = [p for p in points
           if p["offered_per_round"] > p["goodput_per_round"] + 1e-9]
    return {
        "points": points,
        "saturated_points": len(sat),
        "overload_shed": int(points[-1]["shed"]),
        "traces_cold": int(traces_cold),
        "traces_warm": int(traces_warm),
        "one_program": bool(traces_cold <= 1 and traces_warm == 0),
        "cold_wall_s": round(cold_wall, 4),
        "warm_wall_s": round(warm_wall, 4),
    }


def bench_ramp(shape, backend="graph"):
    """One staged ramp (warmup -> steps -> overload) as a LoadReport."""
    r = shape["ramp"]
    prof = staged_ramp(Poisson(rate=shape["rate"]), warmup=r["warmup"],
                       steps=tuple(r["steps"]),
                       rounds_per_stage=r["rounds_per_stage"],
                       overload=r["overload"], seed=7)
    t0 = time.perf_counter()
    rep = run_profile(_group(shape), prof, _policy(shape),
                      backend=backend)
    wall = time.perf_counter() - t0
    out = rep.to_json()
    out["wall_s"] = round(wall, 4)
    return out


_SERVE_ARCH = "loadtest-serve"


def _serve_engines(shape):
    """shape["replicas"] fresh engines of a tiny real dense model; built
    once per suite so the jitted decode stays warm across points."""
    import jax
    from repro.models import layers, registry
    from repro.models.config import ModelConfig
    from repro.models.runtime import Runtime
    from repro.serve.engine import EngineConfig, ServeEngine

    cfg = ModelConfig(name=_SERVE_ARCH, family="dense", n_layers=1,
                      d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                      vocab_size=64, head_dim=16, tie_embeddings=True)
    registry.register(_SERVE_ARCH, lambda: cfg)   # idempotent overwrite
    params = layers.init_tree(registry.param_specs(cfg), jax.random.key(0))
    return [ServeEngine(_SERVE_ARCH, params, cfg,
                        EngineConfig(max_batch=shape["slots"], max_len=32),
                        Runtime())
            for _ in range(shape["replicas"])]


def bench_fused_serve(shape):
    """The serve-plane sweep, each point run through the per-round loop
    AND the fused device program: byte-identical LoadReport JSON, zero
    host hops fused, and the wall-clock goodput ratio (best-of-2 per
    path, same runner) — the fused-dispatch speedup the CI gate holds
    at >= FUSED_SPEEDUP_FLOOR."""
    from repro.load import ServeAdmission
    from repro.serve.fanout import ReplicatedEngine

    engines = _serve_engines(shape)

    def run_once(scale, fused):
        prof = Profile(arrivals=Poisson(rate=shape["rate"]), seed=7,
                       stages=(Stage("warmup", shape["warmup"], scale),
                               Stage("measure", shape["measure"], scale)))
        rep_eng = ReplicatedEngine(engines, subscribers_per_replica=2,
                                   window=4, backend="graph")
        rep_eng.reset()
        t0 = time.perf_counter()
        rep = run_profile(rep_eng, prof,
                          ServeAdmission(queue_cap=shape["queue_cap"]),
                          max_new_tokens=shape["new_tokens"],
                          prompt_len=shape["prompt"], fused=fused)
        return time.perf_counter() - t0, rep

    points = []
    for scale in shape["scales"]:
        walls, reps = {}, {}
        for fused in (False, True):
            walls[fused] = float("inf")
            for _ in range(2):
                w, rep = run_once(scale, fused)
                walls[fused] = min(walls[fused], w)
                reps[fused] = rep
        serve = reps[True].run_report.extras["serve"]
        st = reps[True].stage("measure")
        delivered = reps[True].totals["delivered"]
        points.append({
            "scale": scale,
            "offered_per_round": st.offered_per_round,
            "goodput_per_round": st.goodput_per_round,
            "p99_rounds": st.p99_rounds,
            "shed": int(reps[True].totals["shed"]),
            "fused": bool(serve["fused"]),
            "fused_fallback": serve.get("fused_fallback"),
            "host_hops": int(serve["host_hops"]),
            "json_identical": bool(
                reps[True].json_str() == reps[False].json_str()),
            "wall_unfused_s": round(walls[False], 4),
            "wall_fused_s": round(walls[True], 4),
            "goodput_unfused_per_s": round(delivered / walls[False], 1),
            "goodput_fused_per_s": round(delivered / walls[True], 1),
            "speedup": round(walls[False] / walls[True], 2),
        })
    return {
        "points": points,
        "min_speedup": min(p["speedup"] for p in points),
        "all_fused": all(p["fused"] for p in points),
        "all_zero_host_hops": all(p["host_hops"] == 0 for p in points),
        "all_json_identical": all(p["json_identical"] for p in points),
    }


def run_suite(shape, serve_shape):
    return {"curve": bench_curve(shape), "ramp": bench_ramp(shape),
            "fused_serve": bench_fused_serve(serve_shape)}


def _gate_curve(cur, base, shape):
    """Regression checks for one curve vs its committed baseline."""
    failures = []
    for p, ref in zip(cur["points"], base.get("points", [])):
        tag = f"scale={p['scale']:g}"
        limit = P99_FACTOR * ref["p99_rounds"] + P99_SLACK_ROUNDS
        ok = p["p99_rounds"] <= limit
        print(f"{tag}: p99={p['p99_rounds']:.0f} rounds "
              f"(baseline {ref['p99_rounds']:.0f}, limit {limit:.0f}) "
              f"{'OK' if ok else 'REGRESSION'}")
        if not ok:
            failures.append(f"{tag}.p99_rounds")
        floor = GOODPUT_FACTOR * ref["goodput_per_round"]
        ok = p["goodput_per_round"] >= floor
        print(f"{tag}: goodput={p['goodput_per_round']:.2f}/round "
              f"(baseline {ref['goodput_per_round']:.2f}, floor "
              f"{floor:.2f}) {'OK' if ok else 'REGRESSION'}")
        if not ok:
            failures.append(f"{tag}.goodput")
    lanes = shape["senders"]
    depth_cap = shape["queue_cap"] * lanes
    if cur["points"][-1]["max_queue_depth"] > depth_cap:
        print(f"overload queue depth {cur['points'][-1]['max_queue_depth']}"
              f" exceeds cap x lanes = {depth_cap}")
        failures.append("overload.max_queue_depth")
    if cur["overload_shed"] <= 0:
        print("overload point shed nothing — the sweep no longer crosses "
              "saturation (or admission stopped shedding)")
        failures.append("overload.shed")
    if cur["saturated_points"] < 1:
        print("no saturated point in the sweep")
        failures.append("curve.saturated_points")
    if not cur["one_program"]:
        print(f"trace accounting: cold={cur['traces_cold']} "
              f"warm={cur['traces_warm']} (want <=1 / 0)")
        failures.append("curve.one_program")
    ref_wall = base.get("warm_wall_s")
    if ref_wall is not None:
        limit = WALL_FACTOR * ref_wall + WALL_SLACK_S
        ok = cur["warm_wall_s"] <= limit
        print(f"warm sweep wall: {cur['warm_wall_s']:.3f}s (baseline "
              f"{ref_wall:.3f}s, limit {limit:.3f}s) "
              f"{'OK' if ok else 'REGRESSION'}")
        if not ok:
            failures.append("curve.warm_wall_s")
    return failures


def _gate_fused_serve(fs):
    """The fused-serve contract: every point fuses, takes zero host
    hops, matches the per-round loop byte-for-byte, and the speedup
    ratio holds the floor.  The ratio compares two runs on the SAME
    runner, so unlike absolute wall clocks it cannot flake on a slow
    machine — no baseline needed."""
    failures = []
    for p in fs["points"]:
        tag = f"fused_serve scale={p['scale']:g}"
        if not p["fused"]:
            print(f"{tag}: fell back to the per-round loop "
                  f"({p['fused_fallback']})")
            failures.append(f"{tag}.fused")
        if p["host_hops"] != 0:
            print(f"{tag}: {p['host_hops']} host hops in a fused run "
                  "(want 0)")
            failures.append(f"{tag}.host_hops")
        if not p["json_identical"]:
            print(f"{tag}: fused LoadReport JSON differs from the "
                  "per-round loop's")
            failures.append(f"{tag}.json_identical")
        ok = p["speedup"] >= FUSED_SPEEDUP_FLOOR
        print(f"{tag}: goodput {p['goodput_fused_per_s']}/s fused vs "
              f"{p['goodput_unfused_per_s']}/s per-round loop "
              f"({p['speedup']}x, floor {FUSED_SPEEDUP_FLOOR}x) "
              f"{'OK' if ok else 'REGRESSION'}")
        if not ok:
            failures.append(f"{tag}.speedup")
    return failures


def smoke_gate(baseline_path: Path) -> int:
    results = run_suite(SMOKE, SERVE_SMOKE)
    failures = _gate_fused_serve(results["fused_serve"])
    if not baseline_path.exists():
        print(f"no baseline at {baseline_path}; curve measured only")
        print(json.dumps(results, indent=1))
        return 1 if failures else 0
    base = json.loads(baseline_path.read_text()).get("smoke", {})
    failures += _gate_curve(results["curve"], base.get("curve", {}), SMOKE)
    if failures:
        print(f"loadtest-smoke FAILED: {failures}")
        return 1
    print("loadtest-smoke passed")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="3-point sweep; fail on regression vs baseline")
    ap.add_argument("--json", type=Path, default=BENCH_PATH)
    args = ap.parse_args()
    if args.smoke:
        return smoke_gate(args.json)
    record = {
        "full": run_suite(FULL, SERVE_FULL),
        "smoke": run_suite(SMOKE, SERVE_SMOKE),
        "scenario": {
            "full": {k: (list(v) if isinstance(v, tuple) else v)
                     for k, v in FULL.items()},
            "smoke": {k: (list(v) if isinstance(v, tuple) else v)
                      for k, v in SMOKE.items()},
            "serve_full": {k: (list(v) if isinstance(v, tuple) else v)
                           for k, v in SERVE_FULL.items()},
            "serve_smoke": {k: (list(v) if isinstance(v, tuple) else v)
                            for k, v in SERVE_SMOKE.items()},
        },
    }
    args.json.write_text(json.dumps(record, indent=1) + "\n")
    print(json.dumps(record, indent=1))
    print(f"-> {args.json}")
    full_curve = record["full"]["curve"]
    pts = full_curve["points"]
    goodputs = [p["goodput_per_round"] for p in pts]
    # acceptance: the curve rises to saturation then PLATEAUS (goodput at
    # max offered within 25% of the best point) while p99 stays bounded
    # and shed goes positive — the honest-overload shape.
    fs = record["full"]["fused_serve"]
    ok = (full_curve["saturated_points"] >= 1
          and full_curve["overload_shed"] > 0
          and pts[-1]["offered_per_round"] > pts[-1]["goodput_per_round"]
          and goodputs[-1] >= 0.75 * max(goodputs)
          and pts[-1]["p99_rounds"] <= 3 * (FULL["queue_cap"]
                                            + FULL["inflight_limit"]) + 10
          and pts[-1]["max_queue_depth"]
              <= FULL["queue_cap"] * FULL["senders"]
          and full_curve["one_program"]
          and record["smoke"]["curve"]["one_program"]
          and fs["all_fused"] and fs["all_zero_host_hops"]
          and fs["all_json_identical"]
          and fs["min_speedup"] >= FUSED_SPEEDUP_FLOOR
          and record["smoke"]["fused_serve"]["min_speedup"]
              >= FUSED_SPEEDUP_FLOOR)
    print("acceptance:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
