"""Hot-path wall-clock benchmark — the perf trajectory of the graph/pallas
substrate (compile-once scan, vectorized reconstruction, batched
multi-scenario execution).

Measures, with real wall clocks (unlike benchmarks/run.py, whose numbers
are simulated-time):

* ``repeated_run``  — the same ``Group.run`` twice per backend: the first
  call traces+compiles the scan program, the second hits the jit cache
  (:func:`repro.core.group._scan_program`), so cold/warm is the compile-
  once win and warm is the true per-round/per-message hot-path cost.
* ``window_grid``   — an 8-point Fig.6-style window sweep: 8 sequential
  ``Group.run`` calls vs ONE ``Group.run_batch`` program, asserting the
  per-point delivery logs are byte-identical.
* ``many_topics``   — the many-group dimension (a 16-topic DDS domain):
  ONE stacked compiled program for all topics vs 16 sequential
  single-topic runs, asserting per-topic delivery logs are byte-identical.
  This is the Derecho/DDS-style workload the stacked refactor targets.
* ``serve_fanout``  — the serve plane riding the substrate: G replica
  decode engines (a tiny real dense model) publishing every round's
  admissions + tokens through `Domain.bind`'s streamed stacked program
  (repro.serve.fanout.ReplicatedEngine).  ``cold_s`` includes the decode
  jit + the stream trace; ``warm_s`` (best-of-3, engines reset between
  runs) is the steady-state serve+multicast cost, with ``tok_per_s_warm``
  the wall-clock token rate and ``one_program`` asserting the whole run
  appended a single TRACE_EVENTS entry.
* ``serve_fused``   — the SAME serve workload as ``serve_fanout`` run as
  ONE device-resident program (repro.serve.fused): admission, decode,
  token emission, multicast publish, watermark-gated slot reuse and the
  settle drain all inside one ``lax.while_loop`` — ``host_hops`` must be
  0 (the unfused loop pays one logits readback per decode round plus one
  watermark view per push round) and a warm run must re-trace nothing.
* ``fused_saturation`` — the fused program scaled over replicas x slots
  at fixed per-slot work until wall-clock throughput saturates; the
  curve is the capacity story of the device-resident serve plane.
* ``compile_cache``  — cold-start with and without the JAX persistent
  compilation cache (``REPRO_COMPILATION_CACHE``): three fresh
  subprocesses (cache off / cache populate / cache warm) each timing the
  same cold fused serve run; the delta is what a restarted serving
  process saves when the executable deserializes instead of recompiling.
* ``view_change``   — warm reconfigure-under-traffic: the
  virtual-synchrony cut of a live stream (wedge + ragged trim + epoch
  carry + new-stream hand-off, DESIGN.md Sec. 7) with the padded stack
  shape preserved; ``reused_program`` asserts the new epoch dispatches
  the SAME cached program (no fresh-epoch restart), ``resend_msgs`` that
  traffic was genuinely in flight at the cut.
* ``slot_failure``  — warm reconfigure-with-slot-kill: a serve replica
  loses a publisher (slot) node mid-run; ``cut_s`` is the cut's own
  wall clock (wedge + dead-slot accounting + decode void/re-admit,
  DESIGN.md Secs. 7, 9) and ``reused_program`` asserts the shrunken
  sender set dispatches on the same cached stacked program.

Writes ``BENCH_hotpath.json`` at the repo root (committed — the perf
baseline later PRs regress against).  ``--smoke`` runs tiny shapes and
FAILS (exit 1) if wall-clock regresses >3x against the committed
baseline's ``smoke`` section (plus a small absolute slack so CI-machine
jitter can't flake it); this is the CI ``bench-smoke`` gate.

Run:  PYTHONPATH=src python benchmarks/hotpath.py [--smoke] [--json PATH]
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

from repro.core.group import Group, single_group

ROOT = Path(__file__).resolve().parent.parent
BENCH_PATH = ROOT / "BENCH_hotpath.json"

# Wall clocks of the SAME scenarios measured at the parent commit
# (549ccb4, pre compile-once/vectorized-reconstruction), CPU backend.
# Kept as literals so the before/after story survives the refactor.
PRE_PR = {
    "graph_second_run_s": 0.473,
    "pallas_second_run_s": 0.718,
    "per_round_us_graph_second_run": 2543.2,
    "sequential_8_window_grid_s": 4.228,
    # the committed FULL serve_fanout row at the parent commit (per-round
    # dispatch loop, PR 7 baseline) — the fused serve plane's 5x target
    "serve_fanout_tok_per_s_warm": 487.6,
}

FULL = dict(n=8, senders=4, msgs=150, window=32)
FULL_GRID = (4, 8, 16, 24, 32, 48, 64, 100)
FULL_TOPICS = dict(n_nodes=8, n_topics=16, samples=40)
FULL_SERVE = dict(replicas=2, slots=3, reqs=5, prompt=4, new_tokens=6)
FULL_VC = dict(n=8, senders=4, window=8, rounds=6, per_round=2)
FULL_SLOTKILL = dict(replicas=2, slots=3, reqs=5, prompt=4,
                     new_tokens=6, fail_round=2)
SMOKE = dict(n=4, senders=2, msgs=24, window=8)
SMOKE_GRID = (4, 6, 8, 12)
SMOKE_TOPICS = dict(n_nodes=4, n_topics=16, samples=6)
SMOKE_SERVE = dict(replicas=2, slots=2, reqs=3, prompt=3, new_tokens=4)
SMOKE_VC = dict(n=4, senders=2, window=4, rounds=4, per_round=2)
SMOKE_SLOTKILL = dict(replicas=2, slots=2, reqs=3, prompt=3,
                      new_tokens=4, fail_round=2)

# --smoke regression gate: fail when current > 3x baseline + slack.  The
# slack absorbs CI-runner jitter on the millisecond-scale warm metrics but
# stays far below any real regression: a compile-once revert puts warm_s
# back at ~0.46s (the cold/trace cost), 9x over the 0.05s slack alone.
SMOKE_FACTOR = 3.0
SMOKE_SLACK_S = 0.05


def _scenario(n, senders, msgs, window):
    return single_group(n, n_senders=senders, msg_size=4096, window=window,
                        n_messages=msgs)


def bench_repeated_run(shape, backend="graph"):
    """Cold (trace+compile) vs warm (jit-cache hit) Group.run."""
    cfg = _scenario(**shape)
    t0 = time.perf_counter()
    Group(cfg).run(backend=backend)
    cold = time.perf_counter() - t0
    warm = float("inf")
    for _ in range(3):                       # best-of to de-noise CI boxes
        t0 = time.perf_counter()
        r = Group(cfg).run(backend=backend)
        warm = min(warm, time.perf_counter() - t0)
    per_node = r.delivered_app_msgs / max(len(r.per_node_throughput), 1)
    return {
        "cold_s": round(cold, 4),
        "warm_s": round(warm, 4),
        "speedup_cold_over_warm": round(cold / warm, 1),
        "rounds": r.rounds,
        "per_round_us_warm": round(warm / max(r.rounds, 1) * 1e6, 2),
        "per_msg_us_warm": round(warm / max(per_node, 1) * 1e6, 2),
    }


def _logs_identical(a, b):
    return (a.n_senders == b.n_senders
            and a.delivered_seq == b.delivered_seq
            and len(a.is_app) == len(b.is_app)
            and all(np.array_equal(x, y)
                    for x, y in zip(a.is_app, b.is_app)))


def bench_window_grid(shape, grid, backend="graph"):
    """One batched program vs len(grid) sequential runs, same results."""
    base = dict(shape)
    base.pop("window")
    t0 = time.perf_counter()
    seq_groups = []
    for w in grid:
        g = Group(_scenario(window=w, **base))
        g.run(backend=backend)
        seq_groups.append(g)
    sequential = time.perf_counter() - t0
    g = Group(_scenario(window=grid[0], **base))
    t0 = time.perf_counter()
    reports = g.run_batch(backend=backend, windows=list(grid))
    batched = time.perf_counter() - t0
    identical = all(
        _logs_identical(r.extras["delivery_logs"][gid], gi.delivery_logs[gid])
        for r, gi in zip(reports, seq_groups)
        for gid in gi.delivery_logs)
    return {
        "points": len(grid),
        "sequential_s": round(sequential, 4),
        "batch_s": round(batched, 4),
        "speedup_batch": round(sequential / batched, 1),
        "logs_identical": bool(identical),
    }


def bench_many_topics(shape, backend="graph"):
    """The many-subgroup dimension: one STACKED run of an n_topics-topic
    DDS domain vs n_topics sequential single-topic runs (both warm), with
    byte-identical per-topic delivery logs asserted."""
    from repro.core import dds

    def domain():
        return dds.many_topic_domain(shape["n_nodes"], shape["n_topics"],
                                     subscribers_per_topic=2,
                                     sample_size=4096, window=16)

    samples = shape["samples"]
    g = domain().group(samples_per_publisher=samples)
    t0 = time.perf_counter()
    g.run(backend=backend)
    cold = time.perf_counter() - t0
    warm = float("inf")
    for _ in range(3):
        g = domain().group(samples_per_publisher=samples)
        t0 = time.perf_counter()
        g.run(backend=backend)
        warm = min(warm, time.perf_counter() - t0)
    # sequential per-topic singles (each topic its own compiled program)
    def solos():
        from repro import api
        out = []
        cfg = g.cfg
        for spec in cfg.subgroups:
            out.append(api.Group(api.GroupConfig(
                members=spec.members, subgroups=(spec,), flags=cfg.flags)))
        return out

    for solo in solos():                     # warm every solo program
        solo.run(backend=backend)
    sequential = float("inf")                # best-of, like the stacked side
    for _ in range(3):
        seq_groups = solos()
        t0 = time.perf_counter()
        for solo in seq_groups:
            solo.run(backend=backend)
        sequential = min(sequential, time.perf_counter() - t0)
    identical = all(
        _logs_identical(g.delivery_logs[gid], solo.delivery_logs[0])
        for gid, solo in enumerate(seq_groups))
    return {
        "topics": shape["n_topics"],
        "cold_s": round(cold, 4),
        "stacked_warm_s": round(warm, 4),
        "sequential_warm_s": round(sequential, 4),
        "speedup_stacked": round(sequential / warm, 1),
        "logs_identical": bool(identical),
    }


_SERVE_ARCH = "hotpath-serve"


def _serve_engines(shape):
    """G fresh replica engines of a tiny REAL dense model (compiled decode
    is cached per engine; reset() between runs keeps it warm)."""
    import jax
    from repro.models import layers, registry
    from repro.models.config import ModelConfig
    from repro.models.runtime import Runtime
    from repro.serve.engine import EngineConfig, ServeEngine

    cfg = ModelConfig(name=_SERVE_ARCH, family="dense", n_layers=2,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=512, head_dim=32, tie_embeddings=True)
    registry.register(_SERVE_ARCH, lambda: cfg)   # idempotent overwrite
    params = layers.init_tree(registry.param_specs(cfg), jax.random.key(0))
    return [ServeEngine(_SERVE_ARCH, params, cfg,
                        EngineConfig(max_batch=shape["slots"], max_len=64),
                        Runtime())
            for _ in range(shape["replicas"])], cfg


def bench_serve_fanout(shape, backend="graph"):
    """The serve plane on the stacked substrate: cold (decode jit + stream
    trace) vs warm engine-round loop, one compiled program per run."""
    from repro.core.group import TRACE_EVENTS
    from repro.serve.engine import Request
    from repro.serve.fanout import ReplicatedEngine

    engines, cfg = _serve_engines(shape)

    def run_once(rep):
        rep.reset()
        rng = np.random.default_rng(0)
        for g in range(shape["replicas"]):
            for i in range(shape["reqs"]):
                rep.submit(g, Request(
                    rid=g * 100 + i,
                    prompt=rng.integers(0, cfg.vocab_size, shape["prompt"],
                                        dtype=np.int32),
                    max_new_tokens=shape["new_tokens"]))
        t0 = time.perf_counter()
        report = rep.run()
        return time.perf_counter() - t0, report

    rep = ReplicatedEngine(engines, subscribers_per_replica=2, window=4,
                           backend=backend)
    n0 = len(TRACE_EVENTS)
    cold, report = run_once(rep)
    # at most one stacked trace for a whole run (0 if this scenario
    # shape's program is already cached in-process) — never one per
    # engine round or per replica topic
    one_program = (len(TRACE_EVENTS) - n0) <= 1
    warm, tok_s = float("inf"), 0.0
    for _ in range(3):
        w, report = run_once(rep)
        if w < warm:
            warm, tok_s = w, report.extras["serve"]["tokens_per_s"]
    serve = report.extras["serve"]
    return {
        "replicas": shape["replicas"],
        "slots": shape["slots"],
        "cold_s": round(cold, 4),
        "warm_s": round(warm, 4),
        "tok_per_s_warm": round(tok_s, 1),
        "tokens": serve["tokens"],
        "engine_rounds": serve["engine_rounds"],
        "rdma_writes": report.rdma_writes,
        "one_program": bool(one_program),
    }


def _fill_serve(rep, shape, cfg):
    rep.reset()
    rng = np.random.default_rng(0)
    for g in range(shape["replicas"]):
        for i in range(shape["reqs"]):
            from repro.serve.engine import Request
            rep.submit(g, Request(
                rid=g * 100 + i,
                prompt=rng.integers(0, cfg.vocab_size, shape["prompt"],
                                    dtype=np.int32),
                max_new_tokens=shape["new_tokens"]))


def bench_serve_fused(shape, backend="graph"):
    """The serve_fanout workload as ONE device-resident program: decode
    inside the scan body, zero host hops between rounds.  ``cold_s``
    includes tracing+compiling the fused while_loop; warm runs must hit
    the cached program (``warm_trace_events`` == 0) and report
    ``host_hops`` == 0 — the fused contract the CI smoke gate holds."""
    from repro.core.group import TRACE_EVENTS
    from repro.serve.fanout import ReplicatedEngine

    engines, cfg = _serve_engines(shape)
    rep = ReplicatedEngine(engines, subscribers_per_replica=2, window=4,
                           backend=backend)

    def run_once():
        _fill_serve(rep, shape, cfg)
        t0 = time.perf_counter()
        report = rep.run(fused=True)
        return time.perf_counter() - t0, report

    cold, report = run_once()
    n0 = len(TRACE_EVENTS)
    warm, tok_s = float("inf"), 0.0
    for _ in range(5):
        w, report = run_once()
        if w < warm:
            warm, tok_s = w, report.extras["serve"]["tokens"] / w
    serve = report.extras["serve"]
    return {
        "replicas": shape["replicas"],
        "slots": shape["slots"],
        "cold_s": round(cold, 4),
        "warm_s": round(warm, 4),
        "tok_per_s_warm": round(tok_s, 1),
        "tokens": serve["tokens"],
        "fused": bool(serve["fused"]),
        "fused_fallback": serve.get("fused_fallback"),
        "host_hops": serve["host_hops"],
        "engine_rounds": serve["engine_rounds"],
        "fused_rounds": serve.get("fused_rounds"),
        "warm_trace_events": len(TRACE_EVENTS) - n0,
    }


# fused saturation ladder: replicas x slots at fixed per-slot work
# (reqs = 2*slots keeps every point reusing each slot once)
SATURATION_LADDER = ((1, 2), (1, 4), (1, 8), (2, 4), (2, 8), (2, 16))


def bench_fused_saturation(ladder=SATURATION_LADDER):
    """Scale the fused program over replicas x slots until wall-clock
    throughput saturates.  Each point is a fresh compile (shape-static
    program) — ``cold_s`` is reported but the curve is ``tok_per_s_warm``
    over total slots."""
    from repro.serve.fanout import ReplicatedEngine

    curve = []
    for replicas, slots in ladder:
        shape = dict(replicas=replicas, slots=slots, reqs=2 * slots,
                     prompt=4, new_tokens=6)
        engines, cfg = _serve_engines(shape)
        rep = ReplicatedEngine(engines, subscribers_per_replica=2,
                               window=4)

        def run_once():
            _fill_serve(rep, shape, cfg)
            t0 = time.perf_counter()
            report = rep.run(fused=True)
            return time.perf_counter() - t0, report

        cold, _ = run_once()
        warm, report = float("inf"), None
        for _ in range(3):
            w, r = run_once()
            if w < warm:
                warm, report = w, r
        serve = report.extras["serve"]
        curve.append({
            "replicas": replicas,
            "slots": slots,
            "total_slots": replicas * slots,
            "tokens": serve["tokens"],
            "cold_s": round(cold, 4),
            "warm_s": round(warm, 4),
            "tok_per_s_warm": round(serve["tokens"] / warm, 1),
            "fused": bool(serve["fused"]),
        })
    peak = max(p["tok_per_s_warm"] for p in curve)
    return {
        "curve": curve,
        "peak_tok_per_s": peak,
        # saturated when the last doubling bought < 15% more throughput
        "saturated": bool(curve[-1]["tok_per_s_warm"] < 1.15
                          * curve[-2]["tok_per_s_warm"]),
    }


def bench_compile_cache(shape):
    """Cold-start delta from the JAX persistent compilation cache: three
    fresh subprocesses time the SAME cold fused serve run — cache off,
    cache populate (cold disk), cache warm (deserialize instead of
    recompile).  The probe is this script's own ``--cold-probe`` mode so
    the child measures exactly one process-cold fused run."""
    import os
    import subprocess
    import tempfile

    def probe(extra_env):
        env = {**os.environ, "PYTHONPATH": str(ROOT / "src")}
        env.pop("REPRO_COMPILATION_CACHE", None)
        env.update(extra_env)
        out = subprocess.run(
            [sys.executable, str(Path(__file__).resolve()),
             "--cold-probe"],
            env=env, capture_output=True, text=True, check=True)
        return json.loads(out.stdout.strip().splitlines()[-1])

    with tempfile.TemporaryDirectory() as cache_dir:
        off = probe({})
        populate = probe({"REPRO_COMPILATION_CACHE": cache_dir})
        warm = probe({"REPRO_COMPILATION_CACHE": cache_dir})
    return {
        "cold_run_s_no_cache": off["cold_run_s"],
        "cold_run_s_cache_populate": populate["cold_run_s"],
        "cold_run_s_cache_warm": warm["cold_run_s"],
        "cold_start_delta_s": round(
            off["cold_run_s"] - warm["cold_run_s"], 4),
        "speedup_cold_start": round(
            off["cold_run_s"] / max(warm["cold_run_s"], 1e-9), 2),
    }


def cold_probe(shape) -> dict:
    """Child-process body of ``bench_compile_cache``: one process-cold
    fused serve run, wall-clocked from engine build to report."""
    from repro.serve.fanout import ReplicatedEngine

    engines, cfg = _serve_engines(shape)
    rep = ReplicatedEngine(engines, subscribers_per_replica=2, window=4)
    _fill_serve(rep, shape, cfg)
    t0 = time.perf_counter()
    report = rep.run(fused=True)
    dt = time.perf_counter() - t0
    return {"cold_run_s": round(dt, 4),
            "fused": bool(report.extras["serve"]["fused"])}


def bench_view_change(shape, backend="graph"):
    """Warm reconfigure-under-traffic: the virtual-synchrony cut of a
    LIVE stream (wedge at the SST watermarks, ragged trim, epoch carry,
    new-stream hand-off) with the padded stack shape preserved, so the
    cached one-round program is reused in the new epoch.  The measured
    wall clock is the cut itself — ``reused_program`` asserts the warm
    cycles never re-trace (a fresh-epoch-restart regression would show
    up both here and as a >3x reconfigure_s blowup)."""
    from repro import api
    from repro.core.group import TRACE_EVENTS

    n, s = shape["n"], shape["senders"]
    spec = api.SubgroupSpec(members=tuple(range(n)),
                            senders=tuple(range(s)), msg_size=4096,
                            window=shape["window"], n_messages=0)
    # one spare node outside the subgroup: its failure rolls the epoch
    # (full wedge + cut + resend) without re-shaping the stack
    cfg = api.GroupConfig(members=tuple(range(n + 1)), subgroups=(spec,))
    view = api.View(vid=1, members=tuple(range(n)),
                    senders=tuple(range(n)))

    def cycle():
        stream = api.Group(cfg).stream(backend=backend)
        ready = np.zeros(stream.shape, np.int32)
        ready[0, :s] = shape["per_round"]
        for _ in range(shape["rounds"]):
            stream.step(ready)
        t0 = time.perf_counter()
        s2 = stream.reconfigure(view)
        dt = time.perf_counter() - t0
        for _ in range(shape["rounds"]):
            s2.step(ready)
        report, _ = s2.finish()
        return dt, s2.carry, report

    cycle()                             # warm: trace the stream program
    n0 = len(TRACE_EVENTS)
    best, carry, report = float("inf"), None, None
    for _ in range(3):
        dt, c, r = cycle()
        if dt < best:
            best, carry, report = dt, c, r
    return {
        "reconfigure_s": round(best, 4),
        "resend_msgs": int(carry.total_resend()),
        "delivered_app_msgs": report.delivered_app_msgs,
        "reused_program": bool(len(TRACE_EVENTS) == n0),
    }


def bench_slot_failure(shape, backend="graph"):
    """Warm reconfigure-with-slot-kill: a serve replica loses a SLOT
    (publisher) node mid-run — wedge + cut + dead-slot accounting +
    in-flight decode voided and re-admitted on a surviving slot
    (DESIGN.md Secs. 7, 9).  ``cut_s`` is the cut's own wall clock
    (``ReplicatedEngine.cut_walls``); ``reused_program`` asserts the
    warm cycles never re-trace — the shrunken sender set dispatches on
    the SAME cached stacked program (padded S_max preserved by the
    surviving replica)."""
    from repro.core.group import TRACE_EVENTS
    from repro.serve.engine import Request
    from repro.serve.fanout import ReplicatedEngine

    engines, cfg = _serve_engines(shape)
    rep = ReplicatedEngine(engines, subscribers_per_replica=2, window=4,
                           backend=backend)
    kill = rep._slot_nodes[0][0]         # replica 0, slot 0

    def run_once():
        rep.reset()
        rng = np.random.default_rng(0)
        for g in range(shape["replicas"]):
            for i in range(shape["reqs"]):
                rep.submit(g, Request(
                    rid=g * 100 + i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        shape["prompt"], dtype=np.int32),
                    max_new_tokens=shape["new_tokens"]))
        t0 = time.perf_counter()
        report = rep.run(fail_at={shape["fail_round"]: [kill]})
        return time.perf_counter() - t0, report

    cold, _ = run_once()
    n0 = len(TRACE_EVENTS)
    best_cut, warm, report = float("inf"), float("inf"), None
    for _ in range(3):
        w, r = run_once()
        if rep.cut_walls[0] < best_cut:
            best_cut, report = rep.cut_walls[0], r
        warm = min(warm, w)
    serve = report.extras["serve"]
    vc = rep.view_log[0][2].extras["view_change"]
    return {
        "replicas": shape["replicas"],
        "slots": shape["slots"],
        "cold_s": round(cold, 4),
        "warm_s": round(warm, 4),
        "cut_s": round(best_cut, 4),
        "resend_msgs": int(vc["resend_msgs"]),
        "slot_failures": serve["slot_failures"],
        "voided_requests": serve["voided_requests"],
        "requeued_requests": serve["requeued_requests"],
        "drained": bool(serve["drained"]),
        "reused_program": bool(len(TRACE_EVENTS) == n0),
    }


def run_suite(shape, grid, topics, serve, vc, slotkill):
    return {
        "repeated_run_graph": bench_repeated_run(shape, "graph"),
        "repeated_run_pallas": bench_repeated_run(shape, "pallas"),
        "window_grid_graph": bench_window_grid(shape, grid, "graph"),
        "many_topics_graph": bench_many_topics(topics, "graph"),
        "serve_fanout": bench_serve_fanout(serve, "graph"),
        "serve_fused": bench_serve_fused(serve, "graph"),
        "view_change": bench_view_change(vc, "graph"),
        "slot_failure": bench_slot_failure(slotkill, "graph"),
    }


def smoke_gate(baseline_path: Path) -> int:
    results = run_suite(SMOKE, SMOKE_GRID, SMOKE_TOPICS, SMOKE_SERVE,
                        SMOKE_VC, SMOKE_SLOTKILL)
    if not baseline_path.exists():
        print(f"no baseline at {baseline_path}; smoke measured only")
        print(json.dumps(results, indent=1))
        return 0
    base = json.loads(baseline_path.read_text()).get("smoke", {})
    failures = []
    for bench, metric in (("repeated_run_graph", "warm_s"),
                          ("repeated_run_pallas", "warm_s"),
                          ("window_grid_graph", "batch_s"),
                          ("many_topics_graph", "stacked_warm_s"),
                          ("serve_fanout", "warm_s"),
                          ("serve_fused", "warm_s"),
                          ("view_change", "reconfigure_s"),
                          ("slot_failure", "cut_s")):
        cur = results[bench][metric]
        ref = base.get(bench, {}).get(metric)
        if ref is None:
            continue
        limit = SMOKE_FACTOR * ref + SMOKE_SLACK_S
        status = "OK" if cur <= limit else "REGRESSION"
        print(f"{bench}.{metric}: {cur:.4f}s (baseline {ref:.4f}s, "
              f"limit {limit:.4f}s) {status}")
        if cur > limit:
            failures.append(bench)
    for bench in ("window_grid_graph", "many_topics_graph"):
        if not results[bench]["logs_identical"]:
            print(f"{bench}: batched/stacked logs DIVERGE from sequential")
            failures.append(f"{bench}.logs_identical")
    if not results["serve_fanout"]["one_program"]:
        print("serve_fanout: a run compiled more than one stacked program")
        failures.append("serve_fanout.one_program")
    sf = results["serve_fused"]
    if not sf["fused"]:
        print(f"serve_fused: fell back to the per-round loop "
              f"({sf['fused_fallback']})")
        failures.append("serve_fused.fused")
    if sf["host_hops"] != 0:
        print(f"serve_fused: {sf['host_hops']} host hops in a fused run "
              "(the device-resident contract is zero)")
        failures.append("serve_fused.host_hops")
    if sf["warm_trace_events"] > 1:
        print(f"serve_fused: warm runs appended "
              f"{sf['warm_trace_events']} TRACE_EVENTS entries "
              "(re-tracing per run)")
        failures.append("serve_fused.warm_trace_events")
    # relative throughput floor: fused must beat the per-round loop on
    # the SAME box and shape (absolute floors live in the full run's
    # acceptance, where the machine matches the committed baseline)
    if sf["tok_per_s_warm"] < 1.2 * results["serve_fanout"][
            "tok_per_s_warm"]:
        print(f"serve_fused: {sf['tok_per_s_warm']} tok/s is under 1.2x "
              f"the unfused loop "
              f"({results['serve_fanout']['tok_per_s_warm']} tok/s)")
        failures.append("serve_fused.tok_per_s_warm")
    if not results["view_change"]["reused_program"]:
        print("view_change: a shape-preserving cut re-traced the stream "
              "program (fresh-epoch restart regression)")
        failures.append("view_change.reused_program")
    if not results["slot_failure"]["reused_program"]:
        print("slot_failure: a slot-kill cut re-traced the stream "
              "program (fresh-epoch restart regression)")
        failures.append("slot_failure.reused_program")
    if not results["slot_failure"]["drained"]:
        print("slot_failure: the serve plane failed to drain after the "
              "slot kill")
        failures.append("slot_failure.drained")
    if failures:
        print(f"bench-smoke FAILED: {failures}")
        return 1
    print("bench-smoke passed")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes; fail on >3x regression vs baseline")
    ap.add_argument("--json", type=Path, default=BENCH_PATH)
    ap.add_argument("--cold-probe", action="store_true",
                    help=argparse.SUPPRESS)   # bench_compile_cache child
    args = ap.parse_args()
    if args.cold_probe:
        print(json.dumps(cold_probe(SMOKE_SERVE)))
        return 0
    if args.smoke:
        return smoke_gate(args.json)
    record = {
        "pre_pr_baseline": PRE_PR,
        "full": run_suite(FULL, FULL_GRID, FULL_TOPICS, FULL_SERVE,
                          FULL_VC, FULL_SLOTKILL),
        "smoke": run_suite(SMOKE, SMOKE_GRID, SMOKE_TOPICS, SMOKE_SERVE,
                           SMOKE_VC, SMOKE_SLOTKILL),
        "scenario": {"full": {**FULL, "grid": list(FULL_GRID),
                              "topics": dict(FULL_TOPICS),
                              "serve": dict(FULL_SERVE),
                              "view_change": dict(FULL_VC),
                              "slot_failure": dict(FULL_SLOTKILL)},
                     "smoke": {**SMOKE, "grid": list(SMOKE_GRID),
                               "topics": dict(SMOKE_TOPICS),
                               "serve": dict(SMOKE_SERVE),
                               "view_change": dict(SMOKE_VC),
                               "slot_failure": dict(SMOKE_SLOTKILL)},
                     "fused_saturation": [list(p) for p in
                                          SATURATION_LADDER]},
    }
    full = record["full"]
    record["fused_saturation"] = bench_fused_saturation()
    record["compile_cache"] = bench_compile_cache(SMOKE_SERVE)
    full["vs_pre_pr"] = {
        "graph_second_run_speedup": round(
            PRE_PR["graph_second_run_s"]
            / full["repeated_run_graph"]["warm_s"], 1),
        "pallas_second_run_speedup": round(
            PRE_PR["pallas_second_run_s"]
            / full["repeated_run_pallas"]["warm_s"], 1),
        "window_grid_speedup_vs_pre_pr_sequential": round(
            PRE_PR["sequential_8_window_grid_s"]
            / full["window_grid_graph"]["batch_s"], 1),
        "serve_fused_speedup_vs_pre_pr_serve_row": round(
            full["serve_fused"]["tok_per_s_warm"]
            / PRE_PR["serve_fanout_tok_per_s_warm"], 1),
    }
    args.json.write_text(json.dumps(record, indent=1) + "\n")
    print(json.dumps(record, indent=1))
    print(f"-> {args.json}")
    sat = record["fused_saturation"]
    ok = (full["repeated_run_graph"]["speedup_cold_over_warm"] >= 10
          and full["vs_pre_pr"]["graph_second_run_speedup"] >= 10
          and full["window_grid_graph"]["speedup_batch"] > 1
          and full["window_grid_graph"]["logs_identical"]
          and full["many_topics_graph"]["speedup_stacked"] > 1
          and full["many_topics_graph"]["logs_identical"]
          and full["serve_fanout"]["one_program"]
          and full["serve_fanout"]["tok_per_s_warm"] > 0
          and full["serve_fused"]["fused"]
          and full["serve_fused"]["host_hops"] == 0
          and full["serve_fused"]["warm_trace_events"] <= 1
          # the tentpole: >= 5x the committed per-round serve row at the
          # matched FULL_SERVE shape
          and full["vs_pre_pr"][
              "serve_fused_speedup_vs_pre_pr_serve_row"] >= 5.0
          and all(p["fused"] for p in sat["curve"])
          and sat["peak_tok_per_s"] >= full["serve_fused"][
              "tok_per_s_warm"]
          and record["compile_cache"]["cold_start_delta_s"] > 0
          and full["view_change"]["reused_program"]
          and full["view_change"]["resend_msgs"] > 0
          and full["slot_failure"]["reused_program"]
          and full["slot_failure"]["drained"]
          and full["slot_failure"]["slot_failures"] == 1)
    print("acceptance:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
