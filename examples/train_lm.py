"""End-to-end training driver: train a ~100M-parameter dense LM for a few
hundred steps on the deterministic synthetic stream, with checkpointing
and a mid-run restart to prove restore-from-watermark.

Run:  PYTHONPATH=src python examples/train_lm.py            # ~100M, 300 steps
      PYTHONPATH=src python examples/train_lm.py --tiny     # CI-sized
"""

import argparse
import dataclasses
import tempfile

from repro.models import registry
from repro.models.config import ModelConfig
from repro.models.runtime import Runtime
from repro.optim.adamw import OptConfig
from repro.train.trainer import TrainConfig, Trainer


def model_100m() -> ModelConfig:
    """~109M params, qwen-style dense decoder."""
    return ModelConfig(
        name="dense-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=2048, vocab_size=32000,
        head_dim=64, tie_embeddings=True)


def model_tiny() -> ModelConfig:
    return ModelConfig(
        name="dense-tiny", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=512, head_dim=32,
        tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = model_tiny() if args.tiny else model_100m()
    registry.register(cfg.name, lambda c=cfg: c)
    steps = args.steps or (60 if args.tiny else 300)
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="spindle_ckpt_")

    tcfg = TrainConfig(
        steps=steps,
        seq_len=64 if args.tiny else 256,
        global_batch=4 if args.tiny else 8,
        checkpoint_dir=ckpt,
        checkpoint_every=max(steps // 4, 10),
        log_every=max(steps // 20, 5),
        data_patterns=8 if args.tiny else 64,
        opt=OptConfig(peak_lr=3e-3 if args.tiny else 1e-3,
                      warmup_steps=20, decay_steps=steps),
    )
    print(f"training {cfg.name} for {steps} steps "
          f"(checkpoints -> {ckpt})")
    trainer = Trainer(cfg.name, cfg, tcfg, Runtime())
    trainer.run()
    first = trainer.history[0]["loss"]
    last = trainer.history[-1]["loss"]
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")

    # restart-from-watermark proof: a fresh trainer resumes, not restarts
    trainer2 = Trainer(cfg.name, cfg, dataclasses.replace(
        tcfg, steps=steps + max(steps // 10, 5)), Runtime())
    print("restarting from the checkpoint watermark ...")
    trainer2.run()
    print(f"resumed at step {steps} and reached "
          f"{trainer2.history[-1]['step']} "
          f"(loss {trainer2.history[-1]['loss']:.3f})")


if __name__ == "__main__":
    main()
