"""Serving example: continuous batching over the Spindle slot ring.

Submits a staggered stream of requests against a reduced qwen3 model and
shows opportunistic admission (no waiting for a full batch) plus slot
reuse after delivery.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import numpy as np

from repro.models import layers, registry
from repro.models.runtime import Runtime
from repro.serve.engine import EngineConfig, Request, ServeEngine


def main():
    arch = registry.get("qwen3-1.7b")
    cfg = arch.cfg.reduced()
    params = layers.init_tree(registry.param_specs(cfg), jax.random.key(0))
    engine = ServeEngine("qwen3-1.7b", params, cfg,
                         EngineConfig(max_batch=4, max_len=96),
                         Runtime())
    rng = np.random.default_rng(0)

    # wave 1: more requests than slots -> queueing + continuous admission
    for i in range(7):
        engine.submit(Request(rid=i,
                              prompt=rng.integers(0, cfg.vocab_size, 6,
                                                  dtype=np.int32),
                              max_new_tokens=8 + 2 * (i % 3)))
    t0 = time.time()
    while engine.queue or any(r is not None for r in engine.slot_req):
        engine.step()
        if engine.rounds == 3:   # wave 2 arrives mid-flight
            for i in range(7, 10):
                engine.submit(Request(
                    rid=i, prompt=rng.integers(0, cfg.vocab_size, 4,
                                               dtype=np.int32),
                    max_new_tokens=6))
    dt = time.time() - t0
    done = sorted(engine.completed, key=lambda r: r.rid)
    toks = sum(len(r.tokens_out) for r in done)
    print(f"completed {len(done)} requests / {toks} tokens in {dt:.1f}s "
          f"over {engine.rounds} engine rounds")
    for r in done:
        print(f"  req {r.rid}: {len(r.tokens_out)} tokens "
              f"-> {r.tokens_out[:6]}...")
    assert len(done) == 10


if __name__ == "__main__":
    main()
