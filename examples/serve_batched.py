"""Serving example: replicated continuous batching ON the multicast
substrate (DESIGN.md Sec. 6).

Two replica engines decode a staggered request stream while every round's
admissions and emitted tokens are published as DDS messages — one topic
per replica, slot == SMC sender rank — through ONE stacked compiled
program per engine round (`Domain.bind` -> `GroupStream`).  The demo
shows:

  * opportunistic admission (no waiting for a full batch) with slot reuse
    gated on the multicast delivery watermark (a freed KV slot re-admits
    only once its response is delivered at every subscriber);
  * a client backpressure window (replica 0, slot 0 stalls for three
    rounds) covered by null-send rounds — the other slots' tokens keep
    delivering;
  * the merged report: tokens/s next to multicast duration / RDMA writes.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

import jax
import numpy as np

from repro.models import layers, registry
from repro.models.runtime import Runtime
from repro.serve.engine import EngineConfig, Request, ServeEngine
from repro.serve.fanout import ReplicatedEngine


def main():
    arch = registry.get("qwen3-1.7b")
    cfg = arch.cfg.reduced()
    params = layers.init_tree(registry.param_specs(cfg), jax.random.key(0))
    engines = [ServeEngine("qwen3-1.7b", params, cfg,
                           EngineConfig(max_batch=3, max_len=96),
                           Runtime())
               for _ in range(2)]

    def stall(replica, rnd):             # client backpressure window
        return (0,) if (replica == 0 and 3 <= rnd < 6) else ()

    rep = ReplicatedEngine(engines, subscribers_per_replica=2, window=4,
                           stall_fn=stall)
    rng = np.random.default_rng(0)
    for g in range(2):
        for i in range(5):               # more requests than slots
            rep.submit(g, Request(
                rid=g * 10 + i,
                prompt=rng.integers(0, cfg.vocab_size, 5, dtype=np.int32),
                max_new_tokens=6 + 2 * (i % 2)))

    report = rep.run()
    serve = report.extras["serve"]
    print(f"served {serve['requests']} requests / {serve['tokens']} tokens"
          f" in {serve['engine_rounds']} engine rounds "
          f"({serve['tokens_per_s']:.1f} tok/s wall)")
    print(f"multicast: {report.delivered_app_msgs} app deliveries, "
          f"{report.nulls_sent} nulls sent (stalled rounds: "
          f"{serve['stall_rounds']}), {report.rdma_writes} RDMA writes, "
          f"{report.duration_us:.0f} us modeled duration")
    for g, streams in sorted(rep.completed().items()):
        for i, toks in enumerate(streams):
            print(f"  replica {g} req {i}: {len(toks)} tokens "
                  f"-> {toks[:5]}...")
    refills = {rid: rnd for rid, rnd in rep.admit_rounds.items() if rnd}
    print(f"watermark-gated refills (rid -> engine round): {refills}")
    assert serve["requests"] == 10 and serve["held_slots"] == 0


if __name__ == "__main__":
    main()
