"""Avionics-DDS example (paper Sec. 4.6): topics over subgroups, four QoS
levels, Spindle vs baseline.

A 16-node domain runs one publisher and 15 subscribers on a 10KB Sequence
topic at each QoS level — the paper's Fig. 18 scenario.

Run:  PYTHONPATH=src python examples/dds_pubsub.py
"""

from repro.core import dds
from repro.core.group import RunReport


def bench(qos: dds.QoS, spindle: bool, samples: int = 400) -> RunReport:
    domain = dds.single_topic_domain(n_nodes=16, n_subscribers=15,
                                     qos=qos)
    g = domain.group(samples_per_publisher=samples, spindle=spindle)
    return g.run(backend="des")


def main():
    print("DDS domain: 1 publisher, 15 subscribers, 10KB samples")
    print(f"{'QoS':<18} {'baseline GB/s':>14} {'spindle GB/s':>14} "
          f"{'speedup':>8}")
    for qos in dds.QoS:
        base = bench(qos, spindle=False, samples=150)
        spin = bench(qos, spindle=True)
        sp = spin.throughput_GBps / max(base.throughput_GBps, 1e-9)
        print(f"{qos.value:<18} {base.throughput_GBps:>14.2f} "
              f"{spin.throughput_GBps:>14.2f} {sp:>7.1f}x")

    # multi-topic domain: overlapping subgroups, one active topic
    print("\nmulti-topic domain (10 topics, one active):")
    domain = dds.Domain(n_nodes=16)
    for t in range(10):
        domain.create_topic(f"topic{t}", publishers=[t % 16],
                            subscribers=[n for n in range(16)
                                         if n != t % 16])
    g = domain.group(samples_per_publisher=0, spindle=True)
    # only topic0 publishes: an explicit Group-API send overrides the
    # scenario default for that subgroup
    g.subgroup(0).ordered_send(n=400)
    r = g.run(backend="des")
    print(f"  active-topic throughput with 9 idle topics: "
          f"{r.throughput_GBps:.2f} GB/s (adaptive batching keeps idle "
          f"subgroups nearly free)")


if __name__ == "__main__":
    main()
