"""Elastic training with failures: virtual-synchrony view changes,
straggler null-rounds, and restart from the checkpoint watermark.

A 16-worker data-parallel job loses two nodes mid-run, absorbs a straggler
with null-rounds, admits a replacement, and never stalls.

Run:  PYTHONPATH=src python examples/elastic_failover.py
"""

from repro.train.elastic import ElasticConfig, ElasticRuntime


def main():
    rt = ElasticRuntime(members=list(range(16)),
                        cfg=ElasticConfig(heartbeat_timeout=3,
                                          checkpoint_every=10))
    events = {15: ("fail", 3), 25: ("fail", 7), 30: ("straggle", 11),
              40: ("join", 16)}
    for r in range(60):
        if r in events:
            kind, node = events[r]
            if kind == "fail":
                print(f"  !! node {node} fails at round {r}")
                rt.fail(node)
            elif kind == "straggle":
                print(f"  ~~ node {node} straggles for 4 rounds")
                rt.delay(node, 4)
            elif kind == "join":
                print(f"  ++ node {node} requests to join")
                rt.join(node)
        info = rt.step()
        if info["view_change"] is not None:
            print(f"round {info['round']:3d}: VIEW CHANGE -> view "
                  f"{info['view_change']} members="
                  f"{list(rt.view.members)} "
                  f"restart watermark={rt.restart_watermark()}")
        elif info["null_rounds"]:
            print(f"round {info['round']:3d}: null-rounds for "
                  f"{info['null_rounds']} (dp={info['dp_size']}, "
                  f"{len(info['contributed'])} contributed)")
    print(f"\nfinal view: {rt.view.vid} with {len(rt.view.members)} "
          f"members after {len(rt.view_changes)} view changes")
    assert 16 in rt.view.members and 3 not in rt.view.members
    print("training never stalled: every round either contributed or "
          "null-rounded — the Sec. 3.3 guarantee, at the training layer.")


if __name__ == "__main__":
    main()
