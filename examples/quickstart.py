"""Quickstart: the Spindle techniques in 90 seconds.

1. Simulate the paper's 16-node RDMA testbed: baseline Derecho vs Spindle
   (opportunistic batching + null-sends + lock restructuring).
2. Show the null-send scheme absorbing a delayed sender.
3. Run the in-graph (pure JAX) fused predicate sweep.
4. Fuse gradient buckets with the same opportunistic-batching idea.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gradsync, simulator as sim, sweep


def protocol_demo():
    print("=== 1. atomic multicast, 16 nodes, 10KB messages ===")
    base = sim.run(sim.single_subgroup(
        16, n_messages=300, flags=sim.SpindleFlags.baseline()))
    spin = sim.run(sim.single_subgroup(16, n_messages=1000))
    print(f"  baseline : {base.throughput_GBps:6.2f} GB/s   "
          f"latency {base.mean_latency_us/1e3:7.2f} ms   "
          f"{base.rdma_writes} writes")
    print(f"  spindle  : {spin.throughput_GBps:6.2f} GB/s   "
          f"latency {spin.mean_latency_us/1e3:7.2f} ms   "
          f"{spin.rdma_writes} writes")
    print(f"  speedup  : {spin.throughput_GBps/base.throughput_GBps:.1f}x")


def nullsend_demo():
    print("=== 2. null-sends: one sender delayed 100us per message ===")
    pats = (((0, 3), sim.SenderPattern(inter_send_delay_us=100.0)),)
    on = sim.run(sim.single_subgroup(
        16, n_messages=3000, patterns=pats, target_delivered=15 * 500))
    off = sim.run(sim.single_subgroup(
        16, n_messages=3000, flags=sim.SpindleFlags(null_send=False),
        patterns=pats, target_delivered=15 * 500))
    print(f"  with nulls   : {on.throughput_GBps:6.2f} GB/s "
          f"({on.nulls_sent} nulls sent)")
    print(f"  without      : {off.throughput_GBps:6.2f} GB/s "
          f"(round-robin delivery stalls behind the laggard)")


def sweep_demo():
    print("=== 3. in-graph fused predicate sweep (jit/scan-able) ===")
    state = sweep.SweepState.init(n_members=4, n_senders=3)
    sched = jnp.zeros((30, 3), jnp.int32).at[:, 0].set(1).at[:, 2].set(1)
    state, batches = sweep.run_rounds(state, sched)   # sender 1 silent
    print(f"  app sent {np.asarray(state.app_sent)}  "
          f"nulls {np.asarray(state.nulls_sent)}  "
          f"delivered_seq {np.asarray(state.delivered_num)}")


def gradsync_demo():
    print("=== 4. opportunistic gradient-bucket fusion ===")
    grads = {f"layer{i}": jnp.ones((64, 128)) * i for i in range(20)}
    plan = gradsync.make_plan(grads, target_bytes=256 * 1024)
    n_tensors = len(jax.tree.leaves(grads))
    print(f"  {n_tensors} gradient tensors -> {plan.n_buckets} fused "
          f"collectives "
          f"(sizes: {[plan.bucket_bytes(b)//1024 for b in range(plan.n_buckets)]} KiB)")
    fused = gradsync.fused_psum_mean  # one psum per bucket inside shard_map
    print(f"  reduction entry point: {fused.__name__} "
          f"(see repro.train.steps for the shard_map wiring)")


if __name__ == "__main__":
    protocol_demo()
    nullsend_demo()
    sweep_demo()
    gradsync_demo()
