"""Quickstart: the Spindle techniques in 90 seconds — through the unified
Derecho-style Group API.

1. One `GroupConfig` scenario, run like-for-like on the calibrated DES:
   baseline Derecho vs Spindle (opportunistic batching + null-sends +
   lock restructuring).
2. The null-send scheme absorbing a delayed sender.
3. The SAME scenario on the in-graph (`graph`) and Pallas-kernel
   (`pallas`) backends — one config, three substrates, one RunReport.
4. Fuse gradient buckets with the same opportunistic-batching idea.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro import api
from repro.core import gradsync


def protocol_demo():
    print("=== 1. atomic multicast, 16 nodes, 10KB messages (Group API) ===")
    base = api.Group(api.single_group(
        16, n_messages=300, flags=api.SpindleFlags.baseline())).run("des")
    spin = api.Group(api.single_group(16, n_messages=1000)).run("des")
    print(f"  baseline : {base.throughput_GBps:6.2f} GB/s   "
          f"latency {base.mean_latency_us/1e3:7.2f} ms   "
          f"{base.rdma_writes} writes")
    print(f"  spindle  : {spin.throughput_GBps:6.2f} GB/s   "
          f"latency {spin.mean_latency_us/1e3:7.2f} ms   "
          f"{spin.rdma_writes} writes")
    print(f"  speedup  : {spin.throughput_GBps/base.throughput_GBps:.1f}x")


def nullsend_demo():
    print("=== 2. null-sends: one sender delayed 100us per message ===")
    pats = (((0, 3), api.SenderPattern(inter_send_delay_us=100.0)),)
    on = api.Group(api.single_group(
        16, n_messages=3000, patterns=pats,
        target_delivered=15 * 500)).run("des")
    off = api.Group(api.single_group(
        16, n_messages=3000, flags=api.SpindleFlags(null_send=False),
        patterns=pats, target_delivered=15 * 500)).run("des")
    print(f"  with nulls   : {on.throughput_GBps:6.2f} GB/s "
          f"({on.nulls_sent} nulls sent, "
          f"{on.delivered_null_msgs} null deliveries)")
    print(f"  without      : {off.throughput_GBps:6.2f} GB/s "
          f"(round-robin delivery stalls behind the laggard)")


def backend_demo():
    print("=== 3. one scenario, three substrates ===")
    cfg = api.single_group(4, n_senders=3, msg_size=1024, window=16,
                           n_messages=25)
    seqs = {}
    for backend in ("des", "graph", "pallas"):
        g = api.Group(cfg)
        r = g.run(backend=backend)
        seqs[backend] = g.subgroup(0).delivered(0)
        print(f"  {backend:<7}: {r.delivered_app_msgs} app deliveries, "
              f"{r.nulls_sent} nulls, {r.rdma_writes} writes, "
              f"{r.mean_latency_us:.1f} us mean latency")
    agree = seqs["des"] == seqs["graph"] == seqs["pallas"]
    print(f"  delivered total order identical on all backends: {agree}")


def gradsync_demo():
    print("=== 4. opportunistic gradient-bucket fusion ===")
    grads = {f"layer{i}": jax.numpy.ones((64, 128)) * i for i in range(20)}
    plan = gradsync.make_plan(grads, target_bytes=256 * 1024)
    n_tensors = len(jax.tree.leaves(grads))
    print(f"  {n_tensors} gradient tensors -> {plan.n_buckets} fused "
          f"collectives "
          f"(sizes: {[plan.bucket_bytes(b)//1024 for b in range(plan.n_buckets)]} KiB)")
    fused = gradsync.fused_psum_mean  # one psum per bucket inside shard_map
    print(f"  reduction entry point: {fused.__name__} "
          f"(see repro.train.steps for the shard_map wiring)")


if __name__ == "__main__":
    protocol_demo()
    nullsend_demo()
    backend_demo()
    gradsync_demo()
